//! Planar torque-driven link-tree simulator (see module docs in `mod.rs`).

use crate::util::rng::Rng;

/// One hinged link of the tree.
#[derive(Clone, Debug)]
pub struct LinkSpec {
    /// parent link index, or -1 to attach to the torso
    pub parent: i32,
    /// attachment point along the torso, in [-1, 1] (head..tail); ignored
    /// for links whose parent is another link (they attach at its tip)
    pub attach: f64,
    /// link length (m) — the point mass sits at the tip
    pub length: f64,
    /// link mass (kg)
    pub mass: f64,
    /// rest angle relative to the parent frame (rad)
    pub rest: f64,
    /// torque gear: applied torque = gear * action
    pub gear: f64,
    /// viscous joint damping
    pub damping: f64,
    /// joint limits (rad, relative to rest)
    pub lo: f64,
    pub hi: f64,
}

/// A morphology: torso + link tree + world constants.
#[derive(Clone, Debug)]
pub struct Morphology {
    pub torso_len: f64,
    pub torso_mass: f64,
    /// torso pitch inertia
    pub torso_inertia: f64,
    pub links: Vec<LinkSpec>,
    pub gravity: f64,
    /// initial torso height
    pub init_z: f64,
    /// physics sub-step (s) and control frame-skip
    pub dt: f64,
    pub frame_skip: usize,
    /// ground contact spring / damper / friction
    pub contact_kp: f64,
    pub contact_kd: f64,
    pub friction: f64,
}

impl Morphology {
    pub fn n_joints(&self) -> usize {
        self.links.len()
    }
}

/// Simulator state: generalized coordinates `[x, z, pitch, q...]`.
#[derive(Clone, Debug)]
pub struct ChainSim {
    pub m: Morphology,
    pub q: Vec<f64>,
    pub qd: Vec<f64>,
    /// world positions computed by the last FK pass: per link (tip x, tip z)
    tips: Vec<(f64, f64)>,
    /// world joint anchor positions per link
    anchors: Vec<(f64, f64)>,
    /// world absolute angle per link
    angles: Vec<f64>,
    /// contact flags from the last step (feet touching ground)
    pub contacts: Vec<bool>,
    /// composite inertia per joint (recomputed per step)
    joint_inertia: Vec<f64>,
}

impl ChainSim {
    pub fn new(m: Morphology) -> ChainSim {
        let n = m.n_joints();
        let mut sim = ChainSim {
            q: vec![0.0; 3 + n],
            qd: vec![0.0; 3 + n],
            tips: vec![(0.0, 0.0); n],
            anchors: vec![(0.0, 0.0); n],
            angles: vec![0.0; n],
            contacts: vec![false; n],
            joint_inertia: vec![0.0; n],
            m,
        };
        sim.reset(&mut Rng::new(0));
        sim
    }

    /// Reset to the rest configuration with small random perturbations.
    pub fn reset(&mut self, rng: &mut Rng) {
        let n = self.m.n_joints();
        self.q.iter_mut().for_each(|v| *v = 0.0);
        self.qd.iter_mut().for_each(|v| *v = 0.0);
        self.q[1] = self.m.init_z;
        for i in 0..n {
            self.q[3 + i] = rng.uniform_in(-0.05, 0.05);
            self.qd[3 + i] = rng.uniform_in(-0.05, 0.05);
        }
        self.q[2] = rng.uniform_in(-0.02, 0.02);
        self.fk();
    }

    /// Forward kinematics: world anchors, angles and tips of every link.
    fn fk(&mut self) {
        let (x, z, pitch) = (self.q[0], self.q[1], self.q[2]);
        let half = self.m.torso_len / 2.0;
        for i in 0..self.m.links.len() {
            let l = &self.m.links[i];
            let (anchor, parent_angle) = if l.parent < 0 {
                let ax = x + pitch.cos() * l.attach * half;
                let az = z + pitch.sin() * l.attach * half;
                ((ax, az), pitch)
            } else {
                let p = l.parent as usize;
                debug_assert!(p < i, "links must be topologically sorted");
                (self.tips[p], self.angles[p])
            };
            let ang = parent_angle + l.rest + self.q[3 + i];
            self.anchors[i] = anchor;
            self.angles[i] = ang;
            self.tips[i] =
                (anchor.0 + l.length * ang.cos(), anchor.1 + l.length * ang.sin());
        }
    }

    /// Spring–damper ground force at a point (world), given its velocity.
    fn contact_force(&self, p: (f64, f64), v: (f64, f64)) -> (f64, f64) {
        if p.1 >= 0.0 {
            return (0.0, 0.0);
        }
        let fn_ = (-p.1) * self.m.contact_kp - v.1 * self.m.contact_kd;
        let fn_ = fn_.max(0.0);
        // Coulomb-capped viscous friction
        let ft = (-v.0 * self.m.contact_kd * 2.0)
            .clamp(-self.m.friction * fn_, self.m.friction * fn_);
        (ft, fn_)
    }

    /// World velocity of a link tip (finite chain of hinge contributions).
    fn tip_velocity(&self, i: usize) -> (f64, f64) {
        // v = v_root + w_root x r_root + sum_j (qd_j x r_j) over ancestors
        let (mut vx, mut vz) = (self.qd[0], self.qd[1]);
        let tip = self.tips[i];
        // torso rotation about (x, z)
        let rx = tip.0 - self.q[0];
        let rz = tip.1 - self.q[1];
        vx += -self.qd[2] * rz;
        vz += self.qd[2] * rx;
        // ancestor joints
        let mut j = i as i32;
        while j >= 0 {
            let anchor = self.anchors[j as usize];
            let r = (tip.0 - anchor.0, tip.1 - anchor.1);
            let w = self.qd[3 + j as usize];
            vx += -w * r.1;
            vz += w * r.0;
            j = self.m.links[j as usize].parent;
        }
        (vx, vz)
    }

    /// Composite inertia seen by each joint: sum of distal point masses
    /// times their (current) squared lever arms, plus a floor.
    fn compute_joint_inertia(&mut self) {
        let n = self.m.n_joints();
        for j in 0..n {
            let mut inertia = 0.05; // motor/armature floor
            for i in j..n {
                if self.is_ancestor(j, i) {
                    let anchor = self.anchors[j];
                    let tip = self.tips[i];
                    let d2 = (tip.0 - anchor.0).powi(2)
                        + (tip.1 - anchor.1).powi(2);
                    inertia += self.m.links[i].mass * d2.max(0.01);
                }
            }
            self.joint_inertia[j] = inertia;
        }
    }

    /// Is joint `j` on the chain from link `i` to the torso (inclusive)?
    fn is_ancestor(&self, j: usize, i: usize) -> bool {
        let mut k = i as i32;
        while k >= 0 {
            if k as usize == j {
                return true;
            }
            k = self.m.links[k as usize].parent;
        }
        false
    }

    /// One control step: apply torques (`action` in [-1,1] per joint) for
    /// `frame_skip` physics sub-steps. Returns the average forward velocity
    /// of the torso over the control step.
    pub fn step(&mut self, action: &[f64]) -> f64 {
        let n = self.m.n_joints();
        debug_assert_eq!(action.len(), n);
        let x0 = self.q[0];
        for _ in 0..self.m.frame_skip {
            self.substep(action);
        }
        (self.q[0] - x0) / (self.m.dt * self.m.frame_skip as f64)
    }

    fn substep(&mut self, action: &[f64]) {
        let n = self.m.n_joints();
        let g = self.m.gravity;
        self.fk();
        self.compute_joint_inertia();

        let total_mass: f64 =
            self.m.torso_mass + self.m.links.iter().map(|l| l.mass).sum::<f64>();

        // --- accumulate world forces --------------------------------------
        // (point, force) pairs: gravity at masses, contacts at tips and
        // torso endpoints
        let mut points: Vec<((f64, f64), (f64, f64))> = Vec::with_capacity(2 * n + 4);
        // gravity on torso (at root) and each link tip mass
        points.push(((self.q[0], self.q[1]), (0.0, -self.m.torso_mass * g)));
        for i in 0..n {
            points.push((self.tips[i], (0.0, -self.m.links[i].mass * g)));
        }
        // contacts at link tips
        for i in 0..n {
            let v = self.tip_velocity(i);
            let f = self.contact_force(self.tips[i], v);
            self.contacts[i] = f.1 > 0.0;
            if f != (0.0, 0.0) {
                points.push((self.tips[i], f));
            }
        }
        // contacts at torso endpoints (keeps the torso from sinking)
        let half = self.m.torso_len / 2.0;
        for s in [-1.0, 1.0] {
            let p = (self.q[0] + self.q[2].cos() * s * half,
                     self.q[1] + self.q[2].sin() * s * half);
            let v = (self.qd[0] - self.qd[2] * (p.1 - self.q[1]),
                     self.qd[1] + self.qd[2] * (p.0 - self.q[0]));
            let f = self.contact_force(p, v);
            if f != (0.0, 0.0) {
                points.push((p, f));
            }
        }

        // --- root accelerations -------------------------------------------
        let (mut fx, mut fz, mut tau_root) = (0.0, 0.0, 0.0);
        for &(p, f) in &points {
            fx += f.0;
            fz += f.1;
            // torque about the root
            tau_root += (p.0 - self.q[0]) * f.1 - (p.1 - self.q[1]) * f.0;
        }
        // total pitch inertia: torso + links about root
        let mut i_root = self.m.torso_inertia;
        for i in 0..n {
            let d2 = (self.tips[i].0 - self.q[0]).powi(2)
                + (self.tips[i].1 - self.q[1]).powi(2);
            i_root += self.m.links[i].mass * d2.max(0.01);
        }
        // motor reaction torques act on the parent structure
        let mut tau_reaction = 0.0;
        for i in 0..n {
            tau_reaction -= self.m.links[i].gear * action[i].clamp(-1.0, 1.0);
        }

        let ax = fx / total_mass;
        let az = fz / total_mass;
        let apitch = (tau_root + tau_reaction) / i_root;

        // --- joint accelerations (Jacobian-transpose + diagonal inertia) --
        let mut qdd = vec![0.0f64; n];
        for j in 0..n {
            let anchor = self.anchors[j];
            let mut tau = self.m.links[j].gear * action[j].clamp(-1.0, 1.0);
            tau -= self.m.links[j].damping * self.qd[3 + j];
            // forces applied at points distal to joint j
            for i in 0..n {
                if self.is_ancestor(j, i) {
                    // gravity of mass i
                    let r = (self.tips[i].0 - anchor.0,
                             self.tips[i].1 - anchor.1);
                    tau += r.0 * (-self.m.links[i].mass * g);
                    // contact at tip i
                    let v = self.tip_velocity(i);
                    let f = self.contact_force(self.tips[i], v);
                    tau += r.0 * f.1 - r.1 * f.0;
                }
            }
            // joint limit penalty spring
            let l = &self.m.links[j];
            let qj = self.q[3 + j];
            if qj < l.lo {
                tau += (l.lo - qj) * 200.0 - self.qd[3 + j] * 5.0;
            } else if qj > l.hi {
                tau += (l.hi - qj) * 200.0 - self.qd[3 + j] * 5.0;
            }
            qdd[j] = tau / self.joint_inertia[j];
        }

        // --- semi-implicit Euler -------------------------------------------
        let dt = self.m.dt;
        self.qd[0] += ax * dt;
        self.qd[1] += az * dt;
        self.qd[2] += apitch * dt;
        for j in 0..n {
            self.qd[3 + j] += qdd[j] * dt;
            // numerical safety clamp
            self.qd[3 + j] = self.qd[3 + j].clamp(-50.0, 50.0);
        }
        self.qd[0] = self.qd[0].clamp(-50.0, 50.0);
        self.qd[1] = self.qd[1].clamp(-50.0, 50.0);
        self.qd[2] = self.qd[2].clamp(-50.0, 50.0);
        for k in 0..self.q.len() {
            self.q[k] += self.qd[k] * dt;
        }
        self.fk();
    }

    /// Lowest world point of the structure (for termination checks).
    pub fn lowest_point(&self) -> f64 {
        let mut z = self.q[1];
        for t in &self.tips {
            z = z.min(t.1);
        }
        z
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hopper_like() -> Morphology {
        Morphology {
            torso_len: 0.4,
            torso_mass: 3.0,
            torso_inertia: 0.3,
            links: vec![
                LinkSpec { parent: -1, attach: 0.0, length: 0.45, mass: 1.5,
                           rest: -std::f64::consts::FRAC_PI_2, gear: 60.0,
                           damping: 1.0, lo: -0.8, hi: 0.8 },
                LinkSpec { parent: 0, attach: 0.0, length: 0.5, mass: 1.0,
                           rest: 0.2, gear: 60.0, damping: 1.0,
                           lo: -1.2, hi: 1.2 },
                LinkSpec { parent: 1, attach: 0.0, length: 0.35, mass: 0.6,
                           rest: -0.2, gear: 40.0, damping: 1.0,
                           lo: -0.8, hi: 0.8 },
            ],
            gravity: 9.81,
            init_z: 1.2,
            dt: 0.008,
            frame_skip: 4,
            contact_kp: 6000.0,
            contact_kd: 120.0,
            friction: 1.2,
        }
    }

    #[test]
    fn falls_under_gravity_then_contacts_catch() {
        let mut sim = ChainSim::new(hopper_like());
        let z0 = sim.q[1];
        for _ in 0..20 {
            sim.step(&[0.0, 0.0, 0.0]);
        }
        assert!(sim.q[1] < z0, "should fall");
        // settle for a while: contacts must prevent sinking through ground
        for _ in 0..300 {
            sim.step(&[0.0, 0.0, 0.0]);
        }
        assert!(sim.lowest_point() > -0.3,
                "sank through floor: {}", sim.lowest_point());
        assert!(sim.q.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = ChainSim::new(hopper_like());
        let mut b = ChainSim::new(hopper_like());
        let mut ra = Rng::new(5);
        let mut rb = Rng::new(5);
        a.reset(&mut ra);
        b.reset(&mut rb);
        for i in 0..50 {
            let act = [(i as f64 * 0.1).sin(), -0.3, 0.5];
            a.step(&act);
            b.step(&act);
        }
        assert_eq!(a.q, b.q);
    }

    #[test]
    fn torques_move_joints() {
        let mut sim = ChainSim::new(hopper_like());
        let q0 = sim.q[3];
        for _ in 0..10 {
            sim.step(&[1.0, 0.0, 0.0]);
        }
        assert!((sim.q[3] - q0).abs() > 1e-3, "joint did not move");
    }

    #[test]
    fn energy_does_not_explode() {
        let mut sim = ChainSim::new(hopper_like());
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let act = [rng.uniform_in(-1.0, 1.0),
                       rng.uniform_in(-1.0, 1.0),
                       rng.uniform_in(-1.0, 1.0)];
            sim.step(&act);
        }
        let ke: f64 = sim.qd.iter().map(|v| v * v).sum();
        assert!(ke.is_finite() && ke < 1e5, "ke={ke}");
        assert!(sim.q[1].abs() < 100.0, "z={}", sim.q[1]);
    }

    #[test]
    fn fk_consistency() {
        let mut sim = ChainSim::new(hopper_like());
        sim.q[2] = 0.3;
        sim.q[3] = 0.5;
        sim.fk();
        // first link anchors at torso center
        assert!((sim.anchors[0].0 - sim.q[0]).abs() < 1e-9);
        // chain: link1 anchor == link0 tip
        assert_eq!(sim.anchors[1], sim.tips[0]);
        assert_eq!(sim.anchors[2], sim.tips[1]);
    }
}
