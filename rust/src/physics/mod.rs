//! Planar articulated rigid-body substrate for the locomotion environments.
//!
//! MuJoCo is substituted (DESIGN.md §Substitutions) by a planar
//! composite-rigid-body model: a torso (x, z, pitch) plus a tree of hinged
//! links, torque-driven, with
//!
//! * forward kinematics over the link tree,
//! * spring–damper ground contacts with Coulomb-capped tangential friction
//!   at every link endpoint (and the torso ends),
//! * Jacobian-transpose mapping of contact + gravity forces onto joint
//!   coordinates, with a diagonal composite-inertia approximation of the
//!   mass matrix,
//! * motor-torque reaction on the torso pitch,
//! * joint limits as stiff penalty springs, and
//! * semi-implicit Euler integration.
//!
//! The model keeps the properties the paper's study actually exercises —
//! continuous multi-dimensional state/action, contact-driven non-smooth
//! dynamics, forward-velocity rewards — while staying a few hundred lines
//! of dependency-free rust.

pub mod chain;

pub use chain::{ChainSim, LinkSpec, Morphology};
