//! C emission: render a verified [`QGraph`] as one self-contained,
//! integer-only C file.
//!
//! The emitted translation unit has no dependencies beyond libc
//! (`math.h` for the boundary `rintf`), keeps every weight/threshold as
//! a `static const` ROM literal, and isolates the controller's single
//! floating-point operation — the input quantization — in one boundary
//! function. All f32 constants cross as IEEE-754 bit patterns
//! (`memcpy`-punned), so the file reproduces the rust engines **bit for
//! bit**: the cc-guarded smoke test in `rust/tests/qir.rs` compiles it
//! with `-DQPOL_TEST_MAIN` and diffs raw action bit patterns against
//! [`super::Interpreter`].
//!
//! ```text
//! cc -O2 -c policy.c                         # datapath only
//! cc -O2 -DQPOL_TEST_MAIN policy.c -lm -o p  # stdin/stdout driver
//! ```
//!
//! [`emit_c_registry`] renders a whole registry of policies into one
//! translation unit and deduplicates identical ROMs across policies
//! (common-ROM sharing): a weight, threshold, or tanh ROM whose
//! contents and shape match an earlier policy's is emitted once and
//! aliased with a `#define`. Policies exported at the same output
//! width share the tanh LUT this way even when their weights differ.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::{QGraph, QirBackend};

/// Sanitize a graph name into a C/Verilog identifier — also the file
/// stem every `write_*` helper uses, so artifact ids with separators or
/// other filesystem-hostile characters cannot escape the output dir.
pub fn identifier(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    if s.is_empty() || s.chars().next().unwrap().is_ascii_digit() {
        s.insert(0, 'q');
    }
    s
}

/// Wrap `items` into indented source lines of ~`width` columns.
pub(crate) fn wrap_list(items: &[String], indent: &str, width: usize)
                        -> String {
    let mut out = String::new();
    let mut line = String::from(indent);
    for (i, item) in items.iter().enumerate() {
        let last = i + 1 == items.len();
        let piece =
            if last { item.clone() } else { format!("{item}, ") };
        if line.len() + piece.len() > width && line.len() > indent.len() {
            out.push_str(line.trim_end());
            out.push('\n');
            line = String::from(indent);
        }
        line.push_str(&piece);
    }
    out.push_str(line.trim_end());
    out
}

/// Smallest C integer type whose range covers `[lo, hi]`.
fn c_int_type(lo: i64, hi: i64) -> &'static str {
    if lo >= i8::MIN as i64 && hi <= i8::MAX as i64 {
        "int8_t"
    } else if lo >= i16::MIN as i64 && hi <= i16::MAX as i64 {
        "int16_t"
    } else {
        "int32_t"
    }
}

/// Smallest C integer type holding a `bits`-wide two's-complement
/// value. The narrowing pass shrinks declared accumulator widths, so
/// this is where `--opt` visibly narrows the emitted C datapath.
fn acc_c_type(bits: u32) -> &'static str {
    if bits <= 8 {
        "int8_t"
    } else if bits <= 16 {
        "int16_t"
    } else {
        "int32_t"
    }
}

/// Outcome of cross-policy ROM deduplication in registry emission.
/// `bits_saved` counts the C storage not emitted (int8 weights, int32
/// thresholds and tanh bit patterns).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RomShareReport {
    /// ROMs across all policies (weights + thresholds + tanh LUTs)
    pub roms_total: usize,
    /// ROMs emitted as `#define` aliases of an identical earlier ROM
    pub roms_shared: usize,
    /// storage saved by aliasing, in bits
    pub bits_saved: u64,
}

/// Cross-policy ROM table: canonical content key → owning symbol.
struct RomShare {
    table: HashMap<String, String>,
    report: RomShareReport,
}

/// Consult the ROM table: returns the owning symbol if an identical ROM
/// was already emitted, else records `symbol` as the owner. `None`
/// share (standalone emission) always emits.
fn rom_lookup(share: &mut Option<&mut RomShare>, key: String,
              symbol: &str, stored_bits: u64) -> Option<String> {
    let s = match share {
        Some(s) => s,
        None => return None,
    };
    s.report.roms_total += 1;
    match s.table.get(&key) {
        Some(owner) => {
            s.report.roms_shared += 1;
            s.report.bits_saved += stored_bits;
            Some(owner.clone())
        }
        None => {
            s.table.insert(key, symbol.to_string());
            None
        }
    }
}

/// Emit the graph as a self-contained C file (see module docs).
pub fn emit_c(g: &QGraph) -> Result<String> {
    g.verify()?;
    let layers = g.layers()?;
    anyhow::ensure!(!layers.is_empty(),
                    "graph `{}` has no MatVec/Requant layers to emit",
                    g.name);
    let ident = identifier(&g.name);
    let up = ident.to_ascii_uppercase();
    let max_bound = layers
        .iter()
        .map(|l| l.acc_edge.abs_max())
        .max()
        .unwrap_or(0);

    let mut c = String::new();
    let w = &mut c;
    writeln!(w, "/* {} — integer-only controller datapath emitted by \
                 `qcontrol emit`.", g.name)?;
    writeln!(w, " *")?;
    writeln!(w, " * graph: {}", g.summary())?;
    writeln!(w, " * layer widths: {} (b_in; per-layer w,a — the last \
                 a is b_out)", g.layer_bits()?)?;
    writeln!(w, " *")?;
    writeln!(w, " * Contract: the caller feeds the *normalized* \
                 observation (the frozen")?;
    writeln!(w, " * normalizer travels in the .qpol NORM section); \
                 {ident}_infer projects it")?;
    writeln!(w, " * onto the input lattice — the one floating-point \
                 operation of the")?;
    writeln!(w, " * deployed controller — then runs integer \
                 matrix-vector products with")?;
    writeln!(w, " * i32 accumulators (worst case |acc| <= {max_bound} \
                 < 2^31, checked by")?;
    writeln!(w, " * qir verify), threshold requantization, and a tanh \
                 LUT readout of")?;
    writeln!(w, " * IEEE-754 bit patterns. Bit-identical to qcontrol's \
                 qir Interpreter")?;
    writeln!(w, " * and IntEngine (pinned by rust/tests/qir.rs).")?;
    writeln!(w, " *")?;
    writeln!(w, " * Compile:  cc -O2 -c {ident}.c")?;
    writeln!(w, " *           cc -O2 -DQPOL_TEST_MAIN {ident}.c -lm -o \
                 {ident}")?;
    writeln!(w, " */")?;
    writeln!(w, "#include <math.h>")?;
    writeln!(w, "#include <stdint.h>")?;
    writeln!(w, "#include <string.h>")?;
    emit_c_graph(w, g, &mut None)?;

    // --- optional bit-exact stdio driver --------------------------------
    writeln!(w)?;
    writeln!(w, "#ifdef QPOL_TEST_MAIN")?;
    writeln!(w, "#include <inttypes.h>")?;
    writeln!(w, "#include <stdio.h>")?;
    writeln!(w, "/* Reads {up}_OBS_DIM f32 bit patterns (hex) per \
                 observation from stdin,")?;
    writeln!(w, " * writes {up}_ACT_DIM action bit patterns (hex) per \
                 line — the driver")?;
    writeln!(w, " * behind the emitted-C bit-identity smoke test. */")?;
    writeln!(w, "int main(void) {{")?;
    writeln!(w, "    float obs[{up}_OBS_DIM], act[{up}_ACT_DIM];")?;
    writeln!(w, "    uint32_t bits;")?;
    writeln!(w, "    int i;")?;
    writeln!(w, "    for (;;) {{")?;
    writeln!(w, "        for (i = 0; i < {up}_OBS_DIM; i++) {{")?;
    writeln!(w, "            if (scanf(\"%\" SCNx32, &bits) != 1) \
                 return 0;")?;
    writeln!(w, "            obs[i] = {ident}_f32(bits);")?;
    writeln!(w, "        }}")?;
    writeln!(w, "        {ident}_infer(obs, act);")?;
    writeln!(w, "        for (i = 0; i < {up}_ACT_DIM; i++) {{")?;
    writeln!(w, "            memcpy(&bits, &act[i], 4);")?;
    writeln!(w, "            printf(\"%08\" PRIx32 \"%c\", bits,")?;
    writeln!(w, "                   i + 1 == {up}_ACT_DIM ? '\\n' : ' \
                 ');")?;
    writeln!(w, "        }}")?;
    writeln!(w, "    }}")?;
    writeln!(w, "}}")?;
    writeln!(w, "#endif /* QPOL_TEST_MAIN */")?;
    Ok(c)
}

/// Render every graph of one policy registry into a single driver-free
/// translation unit, deduplicating identical ROMs across policies.
/// Symbols are namespaced by each graph's sanitized identifier; two
/// names that sanitize to the same identifier would silently merge, so
/// that is an error. Returns the C source and the sharing ledger.
pub fn emit_c_registry(graphs: &[QGraph])
                       -> Result<(String, RomShareReport)> {
    anyhow::ensure!(!graphs.is_empty(),
                    "registry emission needs at least one graph");
    let mut seen: HashMap<String, &str> = HashMap::new();
    for g in graphs {
        let id = identifier(&g.name);
        if let Some(prev) = seen.insert(id.clone(), &g.name) {
            anyhow::bail!("policies `{prev}` and `{}` both sanitize to \
                           C identifier `{id}`", g.name);
        }
    }
    let mut share = RomShare {
        table: HashMap::new(),
        report: RomShareReport::default(),
    };
    let mut c = String::new();
    let w = &mut c;
    writeln!(w, "/* {} integer-only controller datapaths emitted by \
                 `qcontrol emit --dir`.", graphs.len())?;
    writeln!(w, " *")?;
    writeln!(w, " * One translation unit per registry: identical \
                 weight/threshold/tanh")?;
    writeln!(w, " * ROMs are emitted once and aliased (`#define`) for \
                 every later policy")?;
    writeln!(w, " * that carries the same contents. Per-policy entry \
                 points are")?;
    writeln!(w, " * `<id>_infer`; the stdio test driver is suppressed \
                 (one `main` per")?;
    writeln!(w, " * binary) — emit a single policy for the bit-exact \
                 driver. */")?;
    writeln!(w, "#include <math.h>")?;
    writeln!(w, "#include <stdint.h>")?;
    writeln!(w, "#include <string.h>")?;
    for g in graphs {
        g.verify()
            .with_context(|| format!("registry policy `{}`", g.name))?;
        writeln!(w)?;
        writeln!(w, "/* ==== {}: {} | layer widths {} ==== */", g.name,
                 g.summary(), g.layer_bits()?)?;
        emit_c_graph(w, g, &mut Some(&mut share))?;
    }
    Ok((c, share.report))
}

/// Emit one graph's defines, helpers, ROMs, and datapath (no includes,
/// no driver). `share` enables cross-policy ROM aliasing.
fn emit_c_graph(w: &mut String, g: &QGraph,
                share: &mut Option<&mut RomShare>) -> Result<()> {
    g.verify()?;
    let layers = g.layers()?;
    anyhow::ensure!(!layers.is_empty(),
                    "graph `{}` has no MatVec/Requant layers to emit",
                    g.name);
    let (s_in, in_r) = g.input_quantizer()?;
    let (lut, out_r) = g.tanh()?;
    let ident = identifier(&g.name);
    let up = ident.to_ascii_uppercase();
    // the rust quantizer guards the scale once; bake the guarded value
    let s_in_bits = s_in.max(1e-12).to_bits();
    // Rust's `NaN as i64` is 0, then clamped onto the lattice
    let nan_q = 0i32.clamp(in_r.qmin, in_r.qmax);
    let maxdim = g.max_int_dim();
    // the scratch buffers only ever hold lattice points (quantized
    // input, requant outputs), so their type follows the widest lattice
    let (buf_lo, buf_hi) = layers
        .iter()
        .map(|l| (l.out_range.qmin as i64, l.out_range.qmax as i64))
        .fold((in_r.qmin as i64, in_r.qmax as i64),
              |(lo, hi), (l, h)| (lo.min(l), hi.max(h)));
    let buf_ty = c_int_type(buf_lo, buf_hi);

    writeln!(w)?;
    writeln!(w, "#define {up}_OBS_DIM {}", g.obs_dim)?;
    writeln!(w, "#define {up}_ACT_DIM {}", g.act_dim)?;
    writeln!(w)?;
    writeln!(w, "static float {ident}_f32(uint32_t bits) {{")?;
    writeln!(w, "    float f;")?;
    writeln!(w, "    memcpy(&f, &bits, 4);")?;
    writeln!(w, "    return f;")?;
    writeln!(w, "}}")?;
    writeln!(w)?;
    writeln!(w, "/* input quantizer: lattice [{}, {}], qs {}, s_in f32 \
                 bits {:#010x} */", in_r.qmin, in_r.qmax, in_r.qs,
             s_in_bits)?;
    writeln!(w, "static int32_t {ident}_quantize_input(float x) {{")?;
    writeln!(w, "    /* rintf: round half to even, matching Rust's \
                 round_ties_even */")?;
    writeln!(w, "    float v = rintf(x / {ident}_f32({s_in_bits:#010x}u) * \
                 {}.0f);", in_r.qs)?;
    writeln!(w, "    if (isnan(v)) return {nan_q}; /* Rust NaN-as-int \
                 cast, clamped */")?;
    writeln!(w, "    if (v <= {}.0f) return {};", in_r.qmin, in_r.qmin)?;
    writeln!(w, "    if (v >= {}.0f) return {};", in_r.qmax, in_r.qmax)?;
    writeln!(w, "    return (int32_t)v;")?;
    writeln!(w, "}}")?;

    // --- ROMs -----------------------------------------------------------
    for (li, l) in layers.iter().enumerate() {
        let n = li + 1;
        let nthr = l.levels - 1;
        writeln!(w)?;
        writeln!(w, "/* layer {n}: MatVec {}x{}, {}-bit weights */",
                 l.rows, l.cols, l.w_bits)?;
        let symbol = format!("{up}_W{n}");
        let items: Vec<String> =
            l.w.iter().map(|v| v.to_string()).collect();
        let key = format!("w:{}x{}:{}", l.rows, l.cols, items.join(","));
        if let Some(owner) = rom_lookup(share, key, &symbol,
                                        (l.rows * l.cols) as u64 * 8) {
            writeln!(w, "#define {symbol} {owner} /* shared ROM */")?;
        } else {
            writeln!(w, "static const int8_t {symbol}[{} * {}] = {{",
                     l.rows, l.cols)?;
            writeln!(w, "{}", wrap_list(&items, "    ", 76))?;
            writeln!(w, "}};")?;
        }
        writeln!(w, "/* layer {n}: ThresholdRequant -> lattice [{}, {}] \
                 ({} levels), acc {} bits */", l.out_range.qmin,
                 l.out_range.qmax, l.levels, l.acc_bits)?;
        let symbol = format!("{up}_T{n}");
        let items: Vec<String> =
            l.thresholds.iter().map(|v| v.to_string()).collect();
        let key = format!("t:{}x{nthr}:{}", l.rows, items.join(","));
        if let Some(owner) = rom_lookup(share, key, &symbol,
                                        (l.rows * nthr) as u64 * 32) {
            writeln!(w, "#define {symbol} {owner} /* shared ROM */")?;
        } else {
            writeln!(w, "static const int32_t {symbol}[{} * {nthr}] = {{",
                     l.rows)?;
            writeln!(w, "{}", wrap_list(&items, "    ", 76))?;
            writeln!(w, "}};")?;
        }
    }
    writeln!(w)?;
    writeln!(w, "/* output tanh LUT over the {}-level lattice, f32 bit \
                 patterns */", lut.len())?;
    let symbol = format!("{up}_TANH");
    let items: Vec<String> = lut
        .iter()
        .map(|v| format!("{:#010x}u", v.to_bits()))
        .collect();
    let key = format!("l:{}:{}", lut.len(), items.join(","));
    if let Some(owner) = rom_lookup(share, key, &symbol,
                                    lut.len() as u64 * 32) {
        writeln!(w, "#define {symbol} {owner} /* shared ROM */")?;
    } else {
        writeln!(w, "static const uint32_t {symbol}[{}] = {{",
                 lut.len())?;
        writeln!(w, "{}", wrap_list(&items, "    ", 76))?;
        writeln!(w, "}};")?;
    }

    // --- datapath -------------------------------------------------------
    writeln!(w)?;
    writeln!(w, "void {ident}_infer(const float obs[{up}_OBS_DIM], float \
                 act[{up}_ACT_DIM]) {{")?;
    writeln!(w, "    {buf_ty} buf_a[{maxdim}], buf_b[{maxdim}];")?;
    writeln!(w, "    {buf_ty} *cur = buf_a, *nxt = buf_b, *swp;")?;
    writeln!(w, "    int j, k, cnt;")?;
    writeln!(w, "    for (j = 0; j < {up}_OBS_DIM; j++)")?;
    writeln!(w, "        cur[j] = ({buf_ty}){ident}_quantize_input(\
                 obs[j]);")?;
    for (li, l) in layers.iter().enumerate() {
        let n = li + 1;
        let nthr = l.levels - 1;
        // the declared accumulator width bounds every partial sum (each
        // lattice contains 0, so per-column contributions straddle 0),
        // so the narrowed C type is safe throughout the dot product
        let acc_ty = acc_c_type(l.acc_bits);
        writeln!(w, "    /* layer {n}: |acc| <= {} (fits {acc_ty}, \
                     verified < 2^31) */", l.acc_edge.abs_max())?;
        writeln!(w, "    for (j = 0; j < {}; j++) {{", l.rows)?;
        writeln!(w, "        {acc_ty} acc = 0;")?;
        writeln!(w, "        for (k = 0; k < {}; k++)", l.cols)?;
        writeln!(w, "            acc = ({acc_ty})(acc + \
                     (int32_t){up}_W{n}[j * {} + k] * cur[k]);", l.cols)?;
        writeln!(w, "        cnt = 0;")?;
        writeln!(w, "        while (cnt < {nthr} && {up}_T{n}[j * {nthr} \
                     + cnt] <= acc)")?;
        writeln!(w, "            cnt++;")?;
        writeln!(w, "        nxt[j] = ({buf_ty})({} + cnt);",
                 l.out_range.qmin)?;
        writeln!(w, "    }}")?;
        writeln!(w, "    swp = cur; cur = nxt; nxt = swp;")?;
    }
    writeln!(w, "    for (j = 0; j < {up}_ACT_DIM; j++)")?;
    writeln!(w, "        act[j] = {ident}_f32({up}_TANH[cur[j] - ({})]);",
             out_r.qmin)?;
    writeln!(w, "}}")?;
    Ok(())
}

/// Emit the graph and write it as `dir/<identifier>.c` (the sanitized
/// name, same stem as the symbols inside). Returns the written path.
pub fn write_c(g: &QGraph, dir: &Path) -> Result<PathBuf> {
    let path = dir.join(format!("{}.c", identifier(&g.name)));
    std::fs::write(&path, emit_c(g)?)
        .with_context(|| format!("write {}", path.display()))?;
    Ok(path)
}

/// [`QirBackend`] marker for C emission.
pub struct CEmitter;

impl QirBackend for CEmitter {
    type Output = String;

    fn name(&self) -> &'static str {
        "emit-c"
    }

    fn compile(&self, g: &QGraph) -> Result<String> {
        emit_c(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qir::{lower, EdgeTy, QOp};
    use crate::quant::{BitCfg, QRange};
    use crate::util::testkit;

    #[test]
    fn emitted_c_is_structurally_complete() {
        let g = lower(&testkit::toy_policy(3, 5, 8, 2,
                                           BitCfg::new(4, 3, 8)))
            .with_name("pend-a");
        let c = emit_c(&g).unwrap();
        // symbols are namespaced by the sanitized policy id, so two
        // emitted controllers link into one binary; only the test-main
        // guard macro stays fixed
        for needle in ["#define PEND_A_OBS_DIM 5",
                       "#define PEND_A_ACT_DIM 2",
                       "PEND_A_W1", "PEND_A_W2", "PEND_A_W3", "PEND_A_T3",
                       "PEND_A_TANH", "pend_a_quantize_input",
                       "pend_a_infer", "QPOL_TEST_MAIN"] {
            assert!(c.contains(needle), "missing `{needle}`");
        }
        // balanced braces is a cheap well-formedness proxy; the real
        // compile check lives in the cc-guarded integration test
        assert_eq!(c.matches('{').count(), c.matches('}').count());
        // integer-only: the sole float math is the boundary quantizer
        assert_eq!(c.matches("rintf").count(), 2, "one use + one comment");
    }

    #[test]
    fn identifier_sanitization() {
        assert_eq!(identifier("pend-a.v2"), "pend_a_v2");
        assert_eq!(identifier("7seg"), "q7seg");
        assert_eq!(identifier(""), "q");
    }

    #[test]
    fn unverifiable_graph_is_rejected() {
        let mut g = lower(&testkit::toy_policy(1, 4, 8, 2,
                                               BitCfg::new(4, 3, 8)));
        g.ops.pop();
        g.edges.pop();
        assert!(emit_c(&g).is_err());
    }

    #[test]
    fn degenerate_graphs_error_instead_of_panicking() {
        let empty = QGraph {
            name: "e".into(),
            obs_dim: 1,
            act_dim: 1,
            ops: vec![],
            edges: vec![],
        };
        let err = emit_c(&empty).unwrap_err().to_string();
        assert!(err.contains("empty graph"), "{err}");
        // boundary ops but no MatVec/Requant legs between them
        let legless = QGraph {
            name: "l".into(),
            obs_dim: 1,
            act_dim: 1,
            ops: vec![QOp::QuantizeInput { s_in: 1.0 },
                      QOp::TanhLut { lut: vec![0.0; 4] }],
            edges: vec![EdgeTy::lattice(1, QRange::new(2, true)),
                        EdgeTy::F32 { dim: 1 }],
        };
        assert!(emit_c(&legless).is_err());
    }

    #[test]
    fn activation_buffers_use_the_narrowest_lattice_type() {
        // every lattice fits i8 → int8_t scratch
        let g = lower(&testkit::toy_policy(1, 4, 8, 2,
                                           BitCfg::new(4, 3, 4)));
        let c = emit_c(&g).unwrap();
        assert!(c.contains("int8_t buf_a"), "{c}");
        // a 16-bit input lattice needs int16_t scratch
        let g = lower(&testkit::toy_policy(1, 4, 8, 2,
                                           BitCfg::new(16, 3, 4)));
        let c = emit_c(&g).unwrap();
        assert!(c.contains("int16_t buf_a"), "{c}");
    }

    #[test]
    fn registry_emission_shares_identical_roms() {
        // the same tensors under two ids: every ROM of the second policy
        // aliases the first's (3 W + 3 T + 1 TANH per policy)
        let p = testkit::toy_policy(5, 4, 8, 2, BitCfg::new(3, 2, 4));
        let a = lower(&p).with_name("pol-a");
        let b = lower(&p).with_name("pol-b");
        let (c, rep) = emit_c_registry(&[a, b]).unwrap();
        assert_eq!(rep.roms_total, 14);
        assert_eq!(rep.roms_shared, 7);
        assert!(rep.bits_saved > 0);
        assert!(c.contains("#define POL_B_W1 POL_A_W1"), "{c}");
        assert!(c.contains("#define POL_B_TANH POL_A_TANH"));
        // driver suppressed: one translation unit, no `main` candidates
        assert!(!c.contains("QPOL_TEST_MAIN"));
        assert_eq!(c.matches('{').count(), c.matches('}').count());
    }

    #[test]
    fn registry_emission_shares_the_tanh_lut_across_policies() {
        // different weights, same output width → the tanh LUT (a pure
        // function of the output lattice) is the shared ROM
        let a = lower(&testkit::toy_policy(1, 4, 8, 2,
                                           BitCfg::new(4, 3, 8)))
            .with_name("p1");
        let b = lower(&testkit::toy_policy(2, 4, 8, 2,
                                           BitCfg::new(4, 3, 8)))
            .with_name("p2");
        let (c, rep) = emit_c_registry(&[a, b]).unwrap();
        assert!(c.contains("#define P2_TANH P1_TANH"), "{c}");
        assert!(rep.roms_shared >= 1);
        assert!(rep.roms_shared < rep.roms_total);
    }

    #[test]
    fn registry_emission_rejects_colliding_identifiers() {
        let p = testkit::toy_policy(1, 4, 8, 2, BitCfg::new(4, 3, 8));
        let a = lower(&p).with_name("pol-a");
        let b = lower(&p).with_name("pol.a"); // sanitizes to pol_a too
        let err = emit_c_registry(&[a, b]).unwrap_err().to_string();
        assert!(err.contains("pol_a"), "{err}");
        assert!(emit_c_registry(&[]).is_err());
    }
}
