//! Reference executor of the integer IR.
//!
//! Walks a verified [`QGraph`] op by op with unbounded (`i64`)
//! accumulators and the exact lattice arithmetic of the exporter — the
//! semantics every other executor is measured against. Because
//! [`QGraph::verify`] bounds the worst-case accumulator of every MatVec
//! to `i32`, the fast `i32` engines (`crate::intinfer::IntEngine`, the
//! emitted C datapath) are bit-identical to this interpreter; the
//! property suite in `rust/tests/qir.rs` pins
//! `Interpreter ≡ IntEngine::infer ≡ IntPolicy::forward_naive`.

use anyhow::{bail, ensure, Result};

use super::{QGraph, QOp, QirBackend};
use crate::quant::quantize;

/// Reference executor over an owned, verified graph.
pub struct Interpreter {
    g: QGraph,
}

impl Interpreter {
    /// Verify the graph and take ownership. The only failure mode is a
    /// graph that does not pass [`QGraph::verify`].
    pub fn new(g: QGraph) -> Result<Interpreter> {
        g.verify()?;
        Ok(Interpreter { g })
    }

    pub fn obs_dim(&self) -> usize {
        self.g.obs_dim
    }

    pub fn act_dim(&self) -> usize {
        self.g.act_dim
    }

    pub fn graph(&self) -> &QGraph {
        &self.g
    }

    /// Execute one (already normalized) observation through the graph.
    pub fn infer(&self, obs: &[f32]) -> Result<Vec<f32>> {
        ensure!(obs.len() == self.g.obs_dim,
                "observation of {} values, graph expects {}", obs.len(),
                self.g.obs_dim);
        let mut x: Vec<i64> = Vec::new();
        for (i, op) in self.g.ops.iter().enumerate() {
            match op {
                QOp::QuantizeInput { s_in } => {
                    let Some(r) = self.lattice_at(i) else {
                        bail!("op {i}: missing input lattice");
                    };
                    x = obs
                        .iter()
                        .map(|&v| quantize(v, *s_in, r) as i64)
                        .collect();
                }
                QOp::MatVec { rows, cols, w, .. } => {
                    let mut next = vec![0i64; *rows];
                    for (j, slot) in next.iter_mut().enumerate() {
                        let wrow = &w[j * cols..(j + 1) * cols];
                        *slot = wrow
                            .iter()
                            .zip(&x)
                            .map(|(&wv, &xv)| wv as i64 * xv)
                            .sum();
                    }
                    x = next;
                }
                QOp::ThresholdRequant { levels, thresholds, .. } => {
                    let Some(r) = self.lattice_at(i) else {
                        bail!("op {i}: missing requant lattice");
                    };
                    let n = levels - 1;
                    for (row, acc) in x.iter_mut().enumerate() {
                        let t = &thresholds[row * n..(row + 1) * n];
                        let cnt =
                            t.partition_point(|&th| (th as i64) <= *acc);
                        *acc = r.qmin as i64 + cnt as i64;
                    }
                }
                QOp::TanhLut { lut } => {
                    let Some(r) = self.lattice_before(i) else {
                        bail!("op {i}: missing output lattice");
                    };
                    return Ok(x
                        .iter()
                        .map(|&q| lut[(q - r.qmin as i64) as usize])
                        .collect());
                }
            }
        }
        bail!("graph did not terminate in a TanhLut");
    }

    fn lattice_at(&self, i: usize) -> Option<crate::quant::QRange> {
        match self.g.edges[i] {
            super::EdgeTy::Int { lattice, .. } => lattice,
            super::EdgeTy::F32 { .. } => None,
        }
    }

    fn lattice_before(&self, i: usize) -> Option<crate::quant::QRange> {
        if i == 0 {
            return None;
        }
        self.lattice_at(i - 1)
    }
}

/// [`QirBackend`] marker for reference execution.
pub struct Interpret;

impl QirBackend for Interpret {
    type Output = Interpreter;

    fn name(&self) -> &'static str {
        "interp"
    }

    fn compile(&self, g: &QGraph) -> Result<Interpreter> {
        Interpreter::new(g.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qir::lower;
    use crate::quant::BitCfg;
    use crate::util::rng::Rng;
    use crate::util::testkit;

    #[test]
    fn matches_the_naive_threshold_forward() {
        let p = testkit::toy_policy(11, 6, 12, 3, BitCfg::new(4, 3, 8));
        let interp = Interpreter::new(lower(&p)).unwrap();
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let mut obs = vec![0.0f32; 6];
            rng.fill_normal(&mut obs);
            assert_eq!(interp.infer(&obs).unwrap(), p.forward_naive(&obs));
        }
    }

    #[test]
    fn dimension_mismatch_is_an_error() {
        let p = testkit::toy_policy(1, 4, 8, 2, BitCfg::new(4, 3, 8));
        let interp = Interpreter::new(lower(&p)).unwrap();
        assert!(interp.infer(&[0.0; 3]).is_err());
        assert!(interp.infer(&[]).is_err());
    }

    #[test]
    fn backend_trait_compiles_the_graph() {
        let g = lower(&testkit::toy_policy(3, 4, 8, 2,
                                           BitCfg::new(4, 3, 8)));
        let interp = Interpret.compile(&g).unwrap();
        assert_eq!(Interpret.name(), "interp");
        assert_eq!(interp.obs_dim(), 4);
        assert_eq!(interp.act_dim(), 2);
    }
}
