//! Verilog emission: render a verified [`QGraph`] as one self-contained
//! module.
//!
//! The module is the **bit-true functional reference** of the datapath —
//! a flat combinational description with every weight/threshold as a ROM
//! literal — not the folded MVAU implementation (PE/SIMD folding,
//! FIFOs, and the resource/timing story live in `crate::synth`). The two
//! floating-point boundary ops stay off-chip, exactly as the paper
//! deploys them (§2.3): the module consumes input-*lattice* points
//! (signed, `in_bits` each, produced by the host-side quantizer the C
//! emitter renders) and emits both the output lattice index and the tanh
//! LUT entry as a 32-bit IEEE-754 bit pattern (an integer ROM lookup).
//!
//! Dialect: Verilog-2001 — `reg` arrays initialized in `initial` blocks
//! (the standard ROM-inference idiom), indexed part-selects, no
//! SystemVerilog constructs — so `iverilog`, Verilator, Vivado, and
//! Yosys all ingest it.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::emit_c::identifier;
use super::{EdgeTy, QGraph, QirBackend};

/// Storage width for a lattice edge: exact for signed lattices, one
/// headroom sign bit for unsigned ones so every operand of the signed
/// datapath arithmetic is itself signed.
fn store_bits(e: EdgeTy) -> u32 {
    if e.signed() { e.bits() } else { e.bits() + 1 }
}

/// Signed two's-complement width for an arbitrary value interval.
fn signed_bits(lo: i64, hi: i64) -> u32 {
    EdgeTy::Int { dim: 1, lo: lo.min(-1), hi: hi.max(0), lattice: None }
        .bits()
}

/// Emit the graph as a self-contained Verilog-2001 module.
pub fn emit_verilog(g: &QGraph) -> Result<String> {
    g.verify()?;
    let layers = g.layers()?;
    let (_s_in, in_r) = g.input_quantizer()?;
    let (lut, out_r) = g.tanh()?;
    let module = identifier(&g.name);
    let in_bits = store_bits(g.edges[0]);
    let last = layers.last().with_context(|| {
        format!("graph `{}` has no MatVec/Requant layers to emit",
                g.name)
    })?;
    let out_bits = EdgeTy::lattice(1, last.out_range).bits();

    let mut v = String::new();
    let w = &mut v;
    writeln!(w, "// {} — integer-only controller datapath emitted by \
                 `qcontrol emit`.", g.name)?;
    writeln!(w, "//")?;
    writeln!(w, "// graph: {}", g.summary())?;
    writeln!(w, "// layer widths: {} (b_in; per-layer w,a — the last \
                 a is b_out)", g.layer_bits()?)?;
    writeln!(w, "//")?;
    writeln!(w, "// Bit-true combinational reference of the verified \
                 integer IR; the")?;
    writeln!(w, "// folded MVAU build (PE/SIMD, FIFOs, resources, \
                 timing) is modeled by")?;
    writeln!(w, "// `qcontrol synth`. Boundary contract: obs_q carries \
                 already-quantized")?;
    writeln!(w, "// input-lattice points [{}, {}] (the FP input \
                 quantizer stays host-side),", in_r.qmin, in_r.qmax)?;
    writeln!(w, "// act_q is the output-lattice index [{}, {}] and \
                 act_f32 the tanh LUT", out_r.qmin, out_r.qmax)?;
    writeln!(w, "// entry as an IEEE-754 bit pattern (integer ROM \
                 lookup).")?;
    writeln!(w, "module {module} (")?;
    writeln!(w, "    input  wire [{}:0] obs_q,   // {} lanes x {in_bits}b \
                 signed", g.obs_dim as u32 * in_bits - 1, g.obs_dim)?;
    writeln!(w, "    output reg  [{}:0] act_q,   // {} lanes x {out_bits}b \
                 signed lattice", g.act_dim as u32 * out_bits - 1,
             g.act_dim)?;
    writeln!(w, "    output reg  [{}:0] act_f32  // {} lanes x f32 bit \
                 pattern", g.act_dim * 32 - 1, g.act_dim)?;
    writeln!(w, ");")?;

    // ---- ROMs ----------------------------------------------------------
    for (li, l) in layers.iter().enumerate() {
        let n = li + 1;
        let nthr = l.levels - 1;
        let tmin = l.thresholds.iter().copied().min().unwrap_or(0) as i64;
        let tmax = l.thresholds.iter().copied().max().unwrap_or(0) as i64;
        // thresholds may sit outside the reachable accumulator range
        // (unreachable levels); size their ROM for the values themselves
        let tw = signed_bits(tmin, tmax).max(l.acc_bits);
        writeln!(w)?;
        writeln!(w, "    // layer {n}: MatVec {}x{} ({}-bit weights), \
                     requant to {} levels", l.rows, l.cols, l.w_bits,
                 l.levels)?;
        writeln!(w, "    reg signed [{}:0] w{n} [0:{}];", l.w_bits - 1,
                 l.rows * l.cols - 1)?;
        writeln!(w, "    reg signed [{}:0] t{n} [0:{}];", tw - 1,
                 l.rows * nthr - 1)?;
        writeln!(w, "    initial begin")?;
        let items: Vec<String> = l
            .w
            .iter()
            .enumerate()
            .map(|(i, x)| format!("w{n}[{i}] = {x};"))
            .collect();
        writeln!(w, "{}", wrap_list_stmts(&items, "        "))?;
        let items: Vec<String> = l
            .thresholds
            .iter()
            .enumerate()
            .map(|(i, x)| format!("t{n}[{i}] = {x};"))
            .collect();
        writeln!(w, "{}", wrap_list_stmts(&items, "        "))?;
        writeln!(w, "    end")?;
    }
    writeln!(w)?;
    writeln!(w, "    // output tanh LUT, f32 bit patterns over the {}-\
                 level lattice", lut.len())?;
    writeln!(w, "    reg [31:0] tanh_lut [0:{}];", lut.len() - 1)?;
    writeln!(w, "    initial begin")?;
    let items: Vec<String> = lut
        .iter()
        .enumerate()
        .map(|(i, x)| format!("tanh_lut[{i}] = 32'h{:08x};", x.to_bits()))
        .collect();
    writeln!(w, "{}", wrap_list_stmts(&items, "        "))?;
    writeln!(w, "    end")?;

    // ---- activation storage --------------------------------------------
    writeln!(w)?;
    writeln!(w, "    reg signed [{}:0] x0 [0:{}];", in_bits - 1,
             g.obs_dim - 1)?;
    for (li, l) in layers.iter().enumerate() {
        let n = li + 1;
        let hw = store_bits(EdgeTy::lattice(1, l.out_range));
        writeln!(w, "    reg signed [{}:0] h{n} [0:{}];", hw - 1,
                 l.rows - 1)?;
        writeln!(w, "    reg signed [{}:0] acc{n};", l.acc_bits - 1)?;
    }
    writeln!(w, "    integer i, j, k, cnt, idx;")?;

    // ---- datapath ------------------------------------------------------
    writeln!(w)?;
    writeln!(w, "    always @* begin")?;
    writeln!(w, "        for (i = 0; i < {}; i = i + 1)", g.obs_dim)?;
    writeln!(w, "            x0[i] = $signed(obs_q[i*{in_bits} +: \
                 {in_bits}]);")?;
    let mut src = "x0".to_string();
    for (li, l) in layers.iter().enumerate() {
        let n = li + 1;
        let nthr = l.levels - 1;
        writeln!(w, "        // layer {n}: |acc| <= {} (fits the {}-bit \
                     accumulator)", l.acc_edge.abs_max(), l.acc_bits)?;
        writeln!(w, "        for (j = 0; j < {}; j = j + 1) begin",
                 l.rows)?;
        writeln!(w, "            acc{n} = 0;")?;
        writeln!(w, "            for (k = 0; k < {}; k = k + 1)",
                 l.cols)?;
        writeln!(w, "                acc{n} = acc{n} + w{n}[j*{} + k] * \
                     {src}[k];", l.cols)?;
        writeln!(w, "            cnt = 0;")?;
        writeln!(w, "            for (k = 0; k < {nthr}; k = k + 1)")?;
        writeln!(w, "                if (t{n}[j*{nthr} + k] <= acc{n})")?;
        writeln!(w, "                    cnt = cnt + 1;")?;
        writeln!(w, "            h{n}[j] = {} + cnt;", l.out_range.qmin)?;
        writeln!(w, "        end")?;
        src = format!("h{n}");
    }
    writeln!(w, "        for (i = 0; i < {}; i = i + 1) begin",
             g.act_dim)?;
    writeln!(w, "            act_q[i*{out_bits} +: {out_bits}] = \
                 {src}[i][{}:0];", out_bits - 1)?;
    writeln!(w, "            idx = {src}[i] - ({});", out_r.qmin)?;
    writeln!(w, "            act_f32[i*32 +: 32] = tanh_lut[idx];")?;
    writeln!(w, "        end")?;
    writeln!(w, "    end")?;
    writeln!(w, "endmodule")?;
    Ok(v)
}

/// Pack already-`;`-terminated statements a few per line.
fn wrap_list_stmts(items: &[String], indent: &str) -> String {
    let mut out = String::new();
    let mut line = String::from(indent);
    for item in items {
        let piece = format!("{item} ");
        if line.len() + piece.len() > 72 && line.len() > indent.len() {
            out.push_str(line.trim_end());
            out.push('\n');
            line = String::from(indent);
        }
        line.push_str(&piece);
    }
    out.push_str(line.trim_end());
    out
}

/// Emit the module and write it as `dir/<identifier>.v` (the sanitized
/// name, matching the module name inside). Returns the written path.
pub fn write_verilog(g: &QGraph, dir: &Path) -> Result<PathBuf> {
    let path = dir.join(format!("{}.v", identifier(&g.name)));
    std::fs::write(&path, emit_verilog(g)?)
        .with_context(|| format!("write {}", path.display()))?;
    Ok(path)
}

/// [`QirBackend`] marker for Verilog emission.
pub struct VerilogEmitter;

impl QirBackend for VerilogEmitter {
    type Output = String;

    fn name(&self) -> &'static str {
        "emit-verilog"
    }

    fn compile(&self, g: &QGraph) -> Result<String> {
        emit_verilog(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qir::lower;
    use crate::quant::BitCfg;
    use crate::util::testkit;

    #[test]
    fn emitted_module_is_structurally_complete() {
        // nb: the name must not contain the substring `end` (e.g.
        // "pend-a") or the begin/end balance count below miscounts
        let g = lower(&testkit::toy_policy(3, 5, 8, 2,
                                           BitCfg::new(4, 3, 8)))
            .with_name("ctrl-a");
        let v = emit_verilog(&g).unwrap();
        assert!(v.starts_with("// ctrl-a"));
        for needle in ["module ctrl_a (", "endmodule", "obs_q", "act_q",
                       "act_f32", "w1 [0:", "t3 [0:", "tanh_lut [0:",
                       "always @*"] {
            assert!(v.contains(needle), "missing `{needle}`");
        }
        // `end` also matches inside `endmodule`; discount it
        assert_eq!(v.matches("begin").count(),
                   v.matches("end").count()
                       - v.matches("endmodule").count());
        // one ROM + one activation array + one accumulator per layer
        for n in 1..=3 {
            assert!(v.contains(&format!("w{n} [0:")));
            assert!(v.contains(&format!("h{n} [0:")));
            assert!(v.contains(&format!("acc{n};")));
        }
    }

    #[test]
    fn degenerate_graphs_error_instead_of_panicking() {
        use crate::qir::QOp;
        use crate::quant::QRange;
        let empty = QGraph {
            name: "e".into(),
            obs_dim: 1,
            act_dim: 1,
            ops: vec![],
            edges: vec![],
        };
        let err = emit_verilog(&empty).unwrap_err().to_string();
        assert!(err.contains("empty graph"), "{err}");
        // boundary ops but no MatVec/Requant legs between them
        let legless = QGraph {
            name: "l".into(),
            obs_dim: 1,
            act_dim: 1,
            ops: vec![QOp::QuantizeInput { s_in: 1.0 },
                      QOp::TanhLut { lut: vec![0.0; 4] }],
            edges: vec![EdgeTy::lattice(1, QRange::new(2, true)),
                        EdgeTy::F32 { dim: 1 }],
        };
        assert!(emit_verilog(&legless).is_err());
    }

    #[test]
    fn port_widths_match_the_lattices() {
        // obs 4 lanes x 6b signed in, 2 lanes x 8b out
        let g = lower(&testkit::toy_policy(1, 4, 8, 2,
                                           BitCfg::new(6, 3, 8)));
        let v = emit_verilog(&g).unwrap();
        assert!(v.contains("input  wire [23:0] obs_q"), "{v}");
        assert!(v.contains("output reg  [15:0] act_q"));
        assert!(v.contains("output reg  [63:0] act_f32"));
    }
}
