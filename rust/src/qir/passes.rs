//! Verified graph-rewrite passes over [`QGraph`] — the optimizing half
//! of the QIR compiler (ROADMAP item 2: "lowers and verifies but never
//! rewrites").
//!
//! A [`Pass`] is a semantics-preserving rewrite: the optimized graph
//! must stay **bit-identical** to the unoptimized one on every input
//! (pinned by the property tests in `rust/tests/qir.rs`). The
//! [`PassManager`] enforces the safety contract mechanically: it runs
//! [`QGraph::verify`] before the first pass and after every pass, and
//! records a per-pass [`PassDelta`] plus the synth-cost-model
//! [`CostEstimate`] before/after, so `pipeline.json` and `qcontrol
//! emit` can show exactly what each rewrite bought.
//!
//! Shipped passes:
//!
//! * [`PruneDeadRows`] — at 2–3-bit lattices whole weight rows quantize
//!   to exactly zero; their accumulator is the constant 0, so the
//!   requant output is a known constant. Remove the row, its
//!   thresholds, and the matching downstream column, folding the
//!   constant into the downstream thresholds (a uniform shift + clamp
//!   preserves the partition-point semantics exactly).
//! * [`FuseTrivialRequant`] — when a requant is *affine-trivial* on the
//!   reachable accumulator interval (its thresholds restricted to that
//!   interval are exactly the consecutive integers, so `out = acc + s`),
//!   the two adjacent MatVecs collapse into one (`W'' = W2·W1`) and the
//!   shift folds into the downstream thresholds.
//! * [`NarrowAccWidths`] — interval-propagate the exact `[lo, hi]`
//!   bounds through every MatVec and shrink the declared `acc_bits` to
//!   the minimal two's-complement width. This narrows the C activation
//!   types, the Verilog accumulator regs, and the synth model's
//!   comparator/FF datapath.
//!
//! The fourth pass of the pipeline, common-ROM sharing, is an
//! *emission-level* rewrite (it dedups identical weight/threshold/tanh
//! ROMs **across** the policies of one registry) and lives in
//! [`super::emit_c::emit_c_registry`].
//!
//! Soundness of the interval machinery: every lattice edge contains 0
//! (signed lattices are symmetric-ish, unsigned start at 0), so each
//! weight's contribution to a row interval is `min(w·lo, w·hi) ≤ 0 ≤
//! max(w·lo, w·hi)`; removing a column can only shrink the interval,
//! and the exact interval is always contained in the crude
//! `±cols·|w|max·|x|max` bound that `verify` checks first — which is
//! why the i64 arithmetic here cannot overflow on a verified graph.

use anyhow::{bail, ensure, Context, Result};

use crate::quant::export::IntPolicy;
use crate::synth::model::{cost_layer, layer_geometry, Design, LayerFold,
                          XC7A15T};
use crate::synth::power::estimate_power;
use crate::util::json::Json;

use super::{lower, EdgeTy, QGraph, QOp};

/// Clock the folding-independent cost probe is evaluated at (the
/// paper's fixed 100 MHz).
const COST_CLOCK_HZ: f64 = 1e8;

// ---------------------------------------------------------------------------
// interval propagation (shared with QGraph::verify)
// ---------------------------------------------------------------------------

/// Exact reachable interval of one MatVec row given input values in
/// `[lo, hi]`. Because every lattice contains 0, the per-weight
/// contribution straddles 0 and partial sums stay inside the final
/// interval — safe in i64 once the crude i32 bound has been checked.
pub(crate) fn row_interval(wrow: &[i8], lo: i64, hi: i64) -> (i64, i64) {
    let mut rlo = 0i64;
    let mut rhi = 0i64;
    for &wv in wrow {
        let w = wv as i64;
        let (a, b) = (w * lo, w * hi);
        rlo += a.min(b);
        rhi += a.max(b);
    }
    (rlo, rhi)
}

/// i64-weight variant for fused products (entries may exceed i8 before
/// the fit check).
fn row_interval_i64(wrow: &[i64], lo: i64, hi: i64) -> (i64, i64) {
    let mut rlo = 0i64;
    let mut rhi = 0i64;
    for &w in wrow {
        let (a, b) = (w * lo, w * hi);
        rlo += a.min(b);
        rhi += a.max(b);
    }
    (rlo, rhi)
}

/// Exact reachable interval of a whole MatVec (union over rows).
pub(crate) fn matvec_interval(w: &[i8], rows: usize, cols: usize,
                              lo: i64, hi: i64) -> (i64, i64) {
    let mut glo = 0i64;
    let mut ghi = 0i64;
    for r in 0..rows {
        let (a, b) = row_interval(&w[r * cols..(r + 1) * cols], lo, hi);
        if r == 0 {
            (glo, ghi) = (a, b);
        } else {
            glo = glo.min(a);
            ghi = ghi.max(b);
        }
    }
    (glo, ghi)
}

// ---------------------------------------------------------------------------
// cost probe
// ---------------------------------------------------------------------------

/// Folding-independent synth-cost snapshot of a graph: every layer
/// fully sequential (PE=SIMD=1, no DSPs), so two snapshots of the same
/// graph before/after a pass are directly comparable — the delta
/// isolates what the *rewrite* changed, not what the folding search
/// happened to pick.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostEstimate {
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub latency_cycles: u64,
    pub energy_per_action_j: f64,
}

impl CostEstimate {
    pub fn of(g: &QGraph) -> Result<CostEstimate> {
        let layers = layer_geometry(g)?
            .iter()
            .map(|l| cost_layer(l.rows, l.cols,
                                LayerFold { pe: 1, simd: 1 },
                                l.w_bits, l.in_bits, l.out_bits,
                                l.acc_bits, 0))
            .collect();
        let design =
            Design { device: XC7A15T, clock_hz: COST_CLOCK_HZ, layers };
        let power = estimate_power(&design, COST_CLOCK_HZ);
        let latency_cycles = design.latency_cycles();
        Ok(CostEstimate {
            luts: design.luts(),
            ffs: design.ffs(),
            bram36: design.bram36(),
            latency_cycles,
            energy_per_action_j: power.total_w
                * latency_cycles as f64 / COST_CLOCK_HZ,
        })
    }
}

// ---------------------------------------------------------------------------
// pass plumbing
// ---------------------------------------------------------------------------

/// Optimization level of the shared `lower → optimize → verify →
/// compile` path. `None` still verifies; `Full` runs the standard
/// rewrite pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptLevel {
    None,
    Full,
}

impl OptLevel {
    pub fn name(self) -> &'static str {
        match self {
            OptLevel::None => "none",
            OptLevel::Full => "full",
        }
    }
}

/// What one pass changed, in graph terms.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PassDelta {
    /// ops removed from the chain (fusion)
    pub ops_removed: u64,
    /// MatVec output rows removed (with their thresholds)
    pub rows_pruned: u64,
    /// downstream MatVec columns removed
    pub cols_pruned: u64,
    /// total declared accumulator bits shaved across requants
    pub acc_bits_saved: u64,
}

impl PassDelta {
    pub fn changed(&self) -> bool {
        *self != PassDelta::default()
    }

    pub fn accumulate(&mut self, o: &PassDelta) {
        self.ops_removed += o.ops_removed;
        self.rows_pruned += o.rows_pruned;
        self.cols_pruned += o.cols_pruned;
        self.acc_bits_saved += o.acc_bits_saved;
    }
}

/// A semantics-preserving graph rewrite. `run` mutates the graph and
/// reports what changed; it must keep the graph bit-identical on every
/// input and leave it in a state [`QGraph::verify`] accepts (the
/// manager re-checks both mechanically).
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, g: &mut QGraph) -> Result<PassDelta>;
}

/// One pass's ledger entry: the graph delta plus the cost-model
/// snapshot on both sides.
#[derive(Clone, Debug)]
pub struct PassOutcome {
    pub name: &'static str,
    pub delta: PassDelta,
    pub cost_before: CostEstimate,
    pub cost_after: CostEstimate,
}

/// Full record of one optimization run — serialized into
/// `pipeline.json` and printed by `qcontrol emit`.
#[derive(Clone, Debug)]
pub struct PassReport {
    pub level: OptLevel,
    pub outcomes: Vec<PassOutcome>,
}

impl PassReport {
    pub fn total_delta(&self) -> PassDelta {
        let mut t = PassDelta::default();
        for o in &self.outcomes {
            t.accumulate(&o.delta);
        }
        t
    }

    /// Human lines for CLI output, one per pass.
    pub fn summary_lines(&self) -> Vec<String> {
        if self.outcomes.is_empty() {
            return vec![format!("opt {}: no rewrite passes run",
                                self.level.name())];
        }
        self.outcomes
            .iter()
            .map(|o| {
                format!(
                    "pass {:<13} -{} ops -{} rows -{} cols -{} acc bits \
                     | luts {} -> {}, ffs {} -> {}, cycles {} -> {}",
                    o.name, o.delta.ops_removed, o.delta.rows_pruned,
                    o.delta.cols_pruned, o.delta.acc_bits_saved,
                    o.cost_before.luts, o.cost_after.luts,
                    o.cost_before.ffs, o.cost_after.ffs,
                    o.cost_before.latency_cycles,
                    o.cost_after.latency_cycles)
            })
            .collect()
    }

    /// The `pipeline.json` per-pass delta schema:
    /// `{"level": ..., "passes": [{name, ops_removed, rows_pruned,
    /// cols_pruned, acc_bits_saved, luts_before, luts_after,
    /// ffs_before, ffs_after, latency_cycles_before,
    /// latency_cycles_after, energy_per_action_j_before,
    /// energy_per_action_j_after}]}`.
    pub fn to_json(&self) -> Json {
        let passes = self
            .outcomes
            .iter()
            .map(|o| {
                Json::obj(vec![
                    ("name", Json::str(o.name)),
                    ("ops_removed", Json::num(o.delta.ops_removed as f64)),
                    ("rows_pruned", Json::num(o.delta.rows_pruned as f64)),
                    ("cols_pruned", Json::num(o.delta.cols_pruned as f64)),
                    ("acc_bits_saved",
                     Json::num(o.delta.acc_bits_saved as f64)),
                    ("luts_before", Json::num(o.cost_before.luts as f64)),
                    ("luts_after", Json::num(o.cost_after.luts as f64)),
                    ("ffs_before", Json::num(o.cost_before.ffs as f64)),
                    ("ffs_after", Json::num(o.cost_after.ffs as f64)),
                    ("latency_cycles_before",
                     Json::num(o.cost_before.latency_cycles as f64)),
                    ("latency_cycles_after",
                     Json::num(o.cost_after.latency_cycles as f64)),
                    ("energy_per_action_j_before",
                     Json::num(o.cost_before.energy_per_action_j)),
                    ("energy_per_action_j_after",
                     Json::num(o.cost_after.energy_per_action_j)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("level", Json::str(self.level.name())),
            ("passes", Json::Arr(passes)),
        ])
    }
}

/// Runs a pass list under the safety contract: verify the input graph,
/// then after every pass re-verify and snapshot the cost model. A pass
/// that breaks an invariant aborts the whole run with a descriptive
/// error naming it — an optimized graph is never silently worse-formed
/// than its source.
pub struct PassManager {
    pub level: OptLevel,
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    /// The standard pipeline for a level. Order (prune → fuse →
    /// narrow) is a heuristic, not a correctness requirement: the
    /// ordering property test runs every permutation.
    pub fn standard(level: OptLevel) -> PassManager {
        let passes: Vec<Box<dyn Pass>> = match level {
            OptLevel::None => vec![],
            OptLevel::Full => vec![
                Box::new(PruneDeadRows),
                Box::new(FuseTrivialRequant),
                Box::new(NarrowAccWidths),
            ],
        };
        PassManager { level, passes }
    }

    /// Custom pass list (ordering/idempotence tests).
    pub fn with_passes(level: OptLevel, passes: Vec<Box<dyn Pass>>)
                       -> PassManager {
        PassManager { level, passes }
    }

    pub fn run(&self, g: &mut QGraph) -> Result<PassReport> {
        g.verify().context("pass input graph fails verification")?;
        let mut outcomes = Vec::new();
        for p in &self.passes {
            let cost_before = CostEstimate::of(g)?;
            let delta = p
                .run(g)
                .with_context(|| format!("pass `{}`", p.name()))?;
            g.verify().with_context(|| {
                format!("pass `{}` broke graph invariants", p.name())
            })?;
            let cost_after = CostEstimate::of(g)?;
            outcomes.push(PassOutcome {
                name: p.name(),
                delta,
                cost_before,
                cost_after,
            });
        }
        Ok(PassReport { level: self.level, outcomes })
    }
}

/// The one shared entry point of every consumer: `lower` the policy,
/// run the standard pipeline at `level` (which verifies before and
/// after), and hand back the graph plus the pass ledger.
pub fn prepare(p: &IntPolicy, level: OptLevel)
               -> Result<(QGraph, PassReport)> {
    let mut g = lower(p);
    let report = PassManager::standard(level).run(&mut g)?;
    Ok((g, report))
}

// ---------------------------------------------------------------------------
// pass 1: dead-row/column pruning
// ---------------------------------------------------------------------------

/// Remove MatVec rows whose weights are all zero (their accumulator is
/// identically 0, so the requant output is the constant
/// `qmin + #{th ≤ 0}`), drop the matching thresholds and downstream
/// columns, and shift the downstream thresholds by the constant's
/// contribution `K_j = Σ_{r∈dead} w2[j][r]·c_r`. Shifted thresholds
/// are clamped into `[lo_j, hi_j+1]` of the *new* downstream row
/// interval — outside that window a threshold's truth value
/// `th ≤ acc` is constant, so the clamp changes nothing and keeps the
/// values small. Sweeps to a fixed point (removing columns can zero
/// further rows). The final MatVec's rows are the action dims and are
/// never pruned; an all-dead MatVec keeps row 0 so the chain stays
/// well-formed.
pub struct PruneDeadRows;

impl Pass for PruneDeadRows {
    fn name(&self) -> &'static str {
        "prune-dead"
    }

    fn run(&self, g: &mut QGraph) -> Result<PassDelta> {
        let mut delta = PassDelta::default();
        loop {
            let mut changed = false;
            let n = g.ops.len();
            if n >= 6 {
                let mut i = 1;
                // non-final MatVecs only: downstream pair at i+2, i+3
                while i + 4 < n {
                    if try_prune_site(g, i, &mut delta)? {
                        changed = true;
                    }
                    i += 2;
                }
            }
            if !changed {
                return Ok(delta);
            }
        }
    }
}

fn try_prune_site(g: &mut QGraph, i: usize, delta: &mut PassDelta)
                  -> Result<bool> {
    let QOp::MatVec { rows, cols, w, .. } = &g.ops[i] else {
        bail!("op {i}: expected MatVec");
    };
    let (rows, cols, w1) = (*rows, *cols, w.clone());
    let dead: Vec<usize> = {
        let mut d: Vec<usize> = (0..rows)
            .filter(|&r| w1[r * cols..(r + 1) * cols]
                .iter()
                .all(|&v| v == 0))
            .collect();
        if d.len() == rows {
            d.remove(0); // keep one row: the chain needs a layer here
        }
        d
    };
    if dead.is_empty() {
        return Ok(false);
    }

    let QOp::ThresholdRequant { levels, thresholds, .. } = &g.ops[i + 1]
    else {
        bail!("op {}: expected ThresholdRequant", i + 1);
    };
    let (levels1, t1) = (*levels, thresholds.clone());
    let EdgeTy::Int { lattice: Some(r1), .. } = g.edges[i + 1] else {
        bail!("op {}: requant output is not a lattice edge", i + 1);
    };
    let QOp::MatVec { rows: rows2, cols: cols2, w: w2, .. } =
        &g.ops[i + 2]
    else {
        bail!("op {}: expected MatVec", i + 2);
    };
    let (rows2, cols2, w2) = (*rows2, *cols2, w2.clone());
    ensure!(cols2 == rows, "op {}: dim chain broken", i + 2);
    let QOp::ThresholdRequant { levels: levels2, thresholds: t2, .. } =
        &g.ops[i + 3]
    else {
        bail!("op {}: expected ThresholdRequant", i + 3);
    };
    let (levels2, t2) = (*levels2, t2.clone());

    // constant output of each dead row: acc ≡ 0
    let nthr1 = levels1 - 1;
    let c_of = |r: usize| -> i64 {
        let t = &t1[r * nthr1..(r + 1) * nthr1];
        r1.qmin as i64 + t.partition_point(|&th| th <= 0) as i64
    };
    // downstream shift per output row
    let k: Vec<i64> = (0..rows2)
        .map(|j| dead.iter()
            .map(|&r| w2[j * cols2 + r] as i64 * c_of(r))
            .sum())
        .collect();

    let keep: Vec<usize> =
        (0..rows).filter(|r| !dead.contains(r)).collect();
    let rows_new = keep.len();
    let mut w1_new = Vec::with_capacity(rows_new * cols);
    let mut t1_new = Vec::with_capacity(rows_new * nthr1);
    for &r in &keep {
        w1_new.extend_from_slice(&w1[r * cols..(r + 1) * cols]);
        t1_new.extend_from_slice(&t1[r * nthr1..(r + 1) * nthr1]);
    }
    let mut w2_new = Vec::with_capacity(rows2 * rows_new);
    for j in 0..rows2 {
        for &r in &keep {
            w2_new.push(w2[j * cols2 + r]);
        }
    }

    // shift + clamp the downstream thresholds; all-or-nothing on i32 fit
    let (l_lo, l_hi) = (r1.qmin as i64, r1.qmax as i64);
    let nthr2 = levels2 - 1;
    let mut t2_new = Vec::with_capacity(t2.len());
    for j in 0..rows2 {
        let (lo_j, hi_j) = row_interval(
            &w2_new[j * rows_new..(j + 1) * rows_new], l_lo, l_hi);
        for &th in &t2[j * nthr2..(j + 1) * nthr2] {
            let v = (th as i64 - k[j]).clamp(lo_j, hi_j + 1);
            if v < i32::MIN as i64 || v > i32::MAX as i64 {
                return Ok(false); // cannot represent; skip whole site
            }
            t2_new.push(v as i32);
        }
    }

    // exact new intervals for both touched accumulator edges
    let EdgeTy::Int { lo: in_lo, hi: in_hi, .. } = g.in_edge(i) else {
        bail!("op {i}: MatVec input is not an integer edge");
    };
    let (a_lo, a_hi) =
        matvec_interval(&w1_new, rows_new, cols, in_lo, in_hi);
    let (b_lo, b_hi) =
        matvec_interval(&w2_new, rows2, rows_new, l_lo, l_hi);

    let removed = dead.len() as u64;
    if let QOp::MatVec { rows, w, .. } = &mut g.ops[i] {
        *rows = rows_new;
        *w = w1_new;
    }
    g.edges[i] =
        EdgeTy::Int { dim: rows_new, lo: a_lo, hi: a_hi, lattice: None };
    if let QOp::ThresholdRequant { thresholds, .. } = &mut g.ops[i + 1] {
        *thresholds = t1_new;
    }
    g.edges[i + 1] = EdgeTy::lattice(rows_new, r1);
    if let QOp::MatVec { cols, w, .. } = &mut g.ops[i + 2] {
        *cols = rows_new;
        *w = w2_new;
    }
    g.edges[i + 2] =
        EdgeTy::Int { dim: rows2, lo: b_lo, hi: b_hi, lattice: None };
    if let QOp::ThresholdRequant { thresholds, .. } = &mut g.ops[i + 3] {
        *thresholds = t2_new;
    }
    delta.rows_pruned += removed;
    delta.cols_pruned += removed;
    Ok(true)
}

// ---------------------------------------------------------------------------
// pass 2: threshold-requant fusion
// ---------------------------------------------------------------------------

/// Fuse `MatVec1 → Requant → MatVec2` into one MatVec where the requant
/// is affine-trivial: for every row `r`, its thresholds restricted to
/// the reachable open-closed window `(lo_r, hi_r]` are exactly the
/// consecutive integers `{lo_r+1, …, hi_r}`, each once — then
/// `out_r = acc_r + s_r` with `s_r = qmin + #{th ≤ lo_r} − lo_r`
/// (checking window *contents*, not just the endpoint difference,
/// because a monotone step function can jump by 2 and then 0 while
/// matching the endpoints). The fused weights `W'' = W2·W1` must fit a
/// signed ≤8-bit lattice and respect the i32 accumulator bound, and the
/// shifted downstream thresholds must fit i32, else the site is
/// skipped whole. The downstream `acc_bits` becomes
/// `max(old, bits(new edge))` since the fused interval is not provably
/// inside the old one.
pub struct FuseTrivialRequant;

impl Pass for FuseTrivialRequant {
    fn name(&self) -> &'static str {
        "fuse-requant"
    }

    fn run(&self, g: &mut QGraph) -> Result<PassDelta> {
        let mut delta = PassDelta::default();
        'restart: loop {
            let n = g.ops.len();
            let mut i = 2; // requant indices with a downstream MatVec
            while i + 4 <= n {
                if try_fuse_site(g, i)? {
                    delta.ops_removed += 2;
                    continue 'restart; // indices shifted; rescan
                }
                i += 2;
            }
            return Ok(delta);
        }
    }
}

fn try_fuse_site(g: &mut QGraph, i: usize) -> Result<bool> {
    let QOp::MatVec { rows, cols, w, .. } = &g.ops[i - 1] else {
        bail!("op {}: expected MatVec", i - 1);
    };
    let (rows1, cols1, w1) = (*rows, *cols, w.clone());
    let QOp::ThresholdRequant { levels, thresholds, .. } = &g.ops[i]
    else {
        bail!("op {i}: expected ThresholdRequant");
    };
    let (levels1, t1) = (*levels, thresholds.clone());
    let EdgeTy::Int { lattice: Some(r1), .. } = g.edges[i] else {
        bail!("op {i}: requant output is not a lattice edge");
    };
    let QOp::MatVec { rows: rows2, cols: cols2, w: w2, .. } =
        &g.ops[i + 1]
    else {
        bail!("op {}: expected MatVec", i + 1);
    };
    let (rows2, cols2, w2) = (*rows2, *cols2, w2.clone());
    ensure!(cols2 == rows1, "op {}: dim chain broken", i + 1);
    let QOp::ThresholdRequant { levels: levels2, acc_bits: acc2,
                                thresholds: t2, .. } = &g.ops[i + 2]
    else {
        bail!("op {}: expected ThresholdRequant", i + 2);
    };
    let (levels2, acc2, t2) = (*levels2, *acc2, t2.clone());

    let EdgeTy::Int { lo: in_lo, hi: in_hi, .. } = g.in_edge(i - 1)
    else {
        bail!("op {}: MatVec input is not an integer edge", i - 1);
    };

    // affine-triviality per requant row on its reachable interval
    let nthr1 = levels1 - 1;
    let mut s = Vec::with_capacity(rows1);
    for r in 0..rows1 {
        let (lo_r, hi_r) = row_interval(
            &w1[r * cols1..(r + 1) * cols1], in_lo, in_hi);
        let row_t = &t1[r * nthr1..(r + 1) * nthr1];
        let window: Vec<i64> = row_t
            .iter()
            .map(|&v| v as i64)
            .filter(|&v| v > lo_r && v <= hi_r)
            .collect();
        if window.len() as i64 != hi_r - lo_r {
            return Ok(false);
        }
        for (kk, &v) in window.iter().enumerate() {
            if v != lo_r + 1 + kk as i64 {
                return Ok(false);
            }
        }
        let below = row_t.iter().filter(|&&v| (v as i64) <= lo_r).count();
        s.push(r1.qmin as i64 + below as i64 - lo_r);
    }

    // fused product W'' = W2·W1 and shift K = W2·s
    let mut wf = vec![0i64; rows2 * cols1];
    for j in 0..rows2 {
        for r in 0..rows1 {
            let w2v = w2[j * cols2 + r] as i64;
            if w2v == 0 {
                continue;
            }
            for c in 0..cols1 {
                wf[j * cols1 + c] += w2v * w1[r * cols1 + c] as i64;
            }
        }
    }
    let k: Vec<i64> = (0..rows2)
        .map(|j| (0..rows1)
            .map(|r| w2[j * cols2 + r] as i64 * s[r])
            .sum())
        .collect();

    // fused weights must live on a signed ≤8-bit lattice
    let wmax = wf.iter().fold(0i64, |m, &v| m.max(v.abs()));
    let Some(w_bits) = (1..=8u32).find(|&b| {
        let r = crate::quant::QRange::new(b, true);
        wf.iter().all(|&v| v >= r.qmin as i64 && v <= r.qmax as i64)
    }) else {
        return Ok(false);
    };
    // and respect the i32 accumulator bound of the fast executors
    let xmax = in_lo.abs().max(in_hi.abs());
    if cols1 as i128 * wmax as i128 * xmax as i128 > i32::MAX as i128 {
        return Ok(false);
    }

    // shift + clamp the downstream thresholds; all-or-nothing on i32 fit
    let nthr2 = levels2 - 1;
    let mut t2_new = Vec::with_capacity(t2.len());
    let mut g_lo = 0i64;
    let mut g_hi = 0i64;
    for j in 0..rows2 {
        let (lo_j, hi_j) = row_interval_i64(
            &wf[j * cols1..(j + 1) * cols1], in_lo, in_hi);
        if j == 0 {
            (g_lo, g_hi) = (lo_j, hi_j);
        } else {
            g_lo = g_lo.min(lo_j);
            g_hi = g_hi.max(hi_j);
        }
        for &th in &t2[j * nthr2..(j + 1) * nthr2] {
            let v = (th as i64 - k[j]).clamp(lo_j, hi_j + 1);
            if v < i32::MIN as i64 || v > i32::MAX as i64 {
                return Ok(false);
            }
            t2_new.push(v as i32);
        }
    }
    let new_edge =
        EdgeTy::Int { dim: rows2, lo: g_lo, hi: g_hi, lattice: None };
    let acc_bits_new = acc2.max(new_edge.bits());

    g.ops[i - 1] = QOp::MatVec {
        rows: rows2,
        cols: cols1,
        w_bits,
        w: wf.iter().map(|&v| v as i8).collect(),
    };
    g.edges[i - 1] = new_edge;
    g.ops.drain(i..i + 2);
    g.edges.drain(i..i + 2);
    if let QOp::ThresholdRequant { acc_bits, thresholds, .. } =
        &mut g.ops[i]
    {
        *acc_bits = acc_bits_new;
        *thresholds = t2_new;
    }
    Ok(true)
}

// ---------------------------------------------------------------------------
// pass 3: accumulator width narrowing
// ---------------------------------------------------------------------------

/// Replace every accumulator edge with the exact interval-propagated
/// `[lo, hi]` and shrink each requant's declared `acc_bits` to the
/// minimal two's-complement width of that interval. Interval inclusion
/// makes `bits()` monotone, so the new width never exceeds the old —
/// the pass only narrows. Downstream this shrinks C activation types,
/// Verilog `acc` reg widths, and the synth model's comparator/FF
/// datapath (where `acc_bits` enters linearly).
pub struct NarrowAccWidths;

impl Pass for NarrowAccWidths {
    fn name(&self) -> &'static str {
        "narrow-acc"
    }

    fn run(&self, g: &mut QGraph) -> Result<PassDelta> {
        let mut delta = PassDelta::default();
        let n = g.ops.len();
        let mut i = 1;
        while i + 2 < n {
            let EdgeTy::Int { lo: in_lo, hi: in_hi, .. } = g.in_edge(i)
            else {
                bail!("op {i}: MatVec input is not an integer edge");
            };
            let (rows, glo, ghi) = {
                let QOp::MatVec { rows, cols, w, .. } = &g.ops[i] else {
                    bail!("op {i}: expected MatVec");
                };
                let (glo, ghi) =
                    matvec_interval(w, *rows, *cols, in_lo, in_hi);
                (*rows, glo, ghi)
            };
            let new_edge =
                EdgeTy::Int { dim: rows, lo: glo, hi: ghi, lattice: None };
            let new_bits = new_edge.bits();
            g.edges[i] = new_edge;
            if let QOp::ThresholdRequant { acc_bits, .. } =
                &mut g.ops[i + 1]
            {
                if new_bits < *acc_bits {
                    delta.acc_bits_saved += (*acc_bits - new_bits) as u64;
                    *acc_bits = new_bits;
                }
            }
            i += 2;
        }
        Ok(delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qir::{interp::Interpreter, QGraph};
    use crate::quant::{BitCfg, QRange};
    use crate::util::testkit;

    fn interp_outputs(g: &QGraph, obs: &[Vec<f32>]) -> Vec<Vec<u32>> {
        let it = Interpreter::new(g.clone()).unwrap();
        obs.iter()
            .map(|o| it.infer(o)
                .unwrap()
                .iter()
                .map(|v| v.to_bits())
                .collect())
            .collect()
    }

    fn probe_obs(dim: usize) -> Vec<Vec<f32>> {
        let mut r = crate::util::rng::Rng::new(17);
        (0..32)
            .map(|_| {
                let mut v = vec![0.0f32; dim];
                r.fill_normal(&mut v);
                v
            })
            .collect()
    }

    /// Hand-built two-layer graph whose first requant is affine-trivial
    /// on the reachable interval, with every fused number precomputed.
    fn fusable_graph() -> QGraph {
        let in_r = QRange::new(2, true); // [-2, 1]
        let mid_r = QRange::new(3, true); // [-4, 3]
        let out_r = QRange::new(2, true); // [-2, 1]
        QGraph {
            name: "fuseme".into(),
            obs_dim: 2,
            act_dim: 2,
            ops: vec![
                QOp::QuantizeInput { s_in: 1.0 },
                // a1 = x0, reachable [-2, 1]
                QOp::MatVec { rows: 1, cols: 2, w_bits: 2,
                              w: vec![1, 0] },
                // thresholds in (-2, 1] are exactly {-1, 0, 1}:
                // out = acc + s with s = -4 + 1 + 2 = -1
                QOp::ThresholdRequant {
                    levels: 8,
                    acc_bits: 4,
                    thresholds: vec![-5, -1, 0, 1, 5, 6, 7],
                },
                QOp::MatVec { rows: 2, cols: 1, w_bits: 2,
                              w: vec![1, -1] },
                QOp::ThresholdRequant {
                    levels: 4,
                    acc_bits: 4,
                    thresholds: vec![-2, -1, 0, 1, 2, 3],
                },
                QOp::TanhLut { lut: vec![-0.9, -0.4, 0.4, 0.9] },
            ],
            edges: vec![
                EdgeTy::lattice(2, in_r),
                EdgeTy::acc(1, 4),
                EdgeTy::lattice(1, mid_r),
                EdgeTy::acc(2, 4),
                EdgeTy::lattice(2, out_r),
                EdgeTy::F32 { dim: 2 },
            ],
        }
    }

    #[test]
    fn fusion_collapses_the_worked_example() {
        let g0 = fusable_graph();
        g0.verify().unwrap();
        let obs = probe_obs(2);
        let want = interp_outputs(&g0, &obs);

        let mut g = g0.clone();
        let delta = FuseTrivialRequant.run(&mut g).unwrap();
        g.verify().unwrap();
        assert_eq!(delta.ops_removed, 2);
        assert_eq!(g.ops.len(), 4);
        let QOp::MatVec { rows, cols, w_bits, w } = &g.ops[1] else {
            panic!("fused op is not a MatVec");
        };
        assert_eq!((*rows, *cols, *w_bits), (2, 2, 2));
        assert_eq!(w, &vec![1, 0, -1, 0]); // W'' = W2 · W1
        let QOp::ThresholdRequant { thresholds, .. } = &g.ops[2] else {
            panic!("op 2 is not a requant");
        };
        // K = (-1, 1): row0 shifted by +1, row1 by -1, clamps inert
        assert_eq!(thresholds, &vec![-1, 0, 1, 0, 1, 2]);
        assert_eq!(interp_outputs(&g, &obs), want);
    }

    #[test]
    fn prune_removes_planted_dead_rows_bit_identically() {
        let p = testkit::sparse_toy_policy(11, 5, 16, 2,
                                           BitCfg::new(3, 2, 6), 4, 4);
        let g0 = lower(&p);
        g0.verify().unwrap();
        let obs = probe_obs(5);
        let want = interp_outputs(&g0, &obs);

        let mut g = g0.clone();
        let delta = PruneDeadRows.run(&mut g).unwrap();
        g.verify().unwrap();
        assert!(delta.rows_pruned >= 8, "planted 4+4 dead rows, \
                 pruned {}", delta.rows_pruned);
        assert_eq!(delta.rows_pruned, delta.cols_pruned);
        assert_eq!(interp_outputs(&g, &obs), want);
    }

    #[test]
    fn narrow_shrinks_declared_widths_bit_identically() {
        let p = testkit::toy_policy(5, 4, 12, 2, BitCfg::new(2, 2, 2));
        let g0 = lower(&p);
        g0.verify().unwrap();
        let obs = probe_obs(4);
        let want = interp_outputs(&g0, &obs);

        let mut g = g0.clone();
        let delta = NarrowAccWidths.run(&mut g).unwrap();
        g.verify().unwrap();
        assert!(delta.acc_bits_saved > 0,
                "exact intervals should beat the crude exporter bound");
        assert_eq!(interp_outputs(&g, &obs), want);
        // idempotent: a second run changes nothing
        let again = NarrowAccWidths.run(&mut g).unwrap();
        assert!(!again.changed());
    }

    #[test]
    fn manager_records_strict_cost_reduction_at_2bit() {
        let p = testkit::sparse_toy_policy(3, 6, 24, 2,
                                           BitCfg::new(2, 2, 2), 6, 6);
        let (g, report) = prepare(&p, OptLevel::Full).unwrap();
        g.verify().unwrap();
        assert_eq!(report.outcomes.len(), 3);
        let first = &report.outcomes[0].cost_before;
        let last = &report.outcomes[report.outcomes.len() - 1].cost_after;
        assert!(last.luts < first.luts, "luts {} -> {}", first.luts,
                last.luts);
        assert!(last.ffs < first.ffs, "ffs {} -> {}", first.ffs,
                last.ffs);
        assert!(report.total_delta().changed());
        // report surfaces are well-formed
        assert_eq!(report.summary_lines().len(), 3);
        let j = report.to_json();
        assert_eq!(j.get("level").unwrap().as_str().unwrap(), "full");
        assert_eq!(j.get("passes").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn prepare_none_is_lower_plus_verify() {
        let p = testkit::toy_policy(9, 4, 8, 2, BitCfg::new(4, 3, 8));
        let (g, report) = prepare(&p, OptLevel::None).unwrap();
        assert!(report.outcomes.is_empty());
        assert_eq!(g.ops.len(), lower(&p).ops.len());
        let mut expect = lower(&p);
        expect.name = g.name.clone();
        assert_eq!(g, expect);
    }

    #[test]
    fn manager_rejects_unverifiable_input() {
        let p = testkit::toy_policy(9, 4, 8, 2, BitCfg::new(4, 3, 8));
        let mut g = lower(&p);
        g.edges.pop();
        let err = PassManager::standard(OptLevel::Full)
            .run(&mut g)
            .unwrap_err();
        assert!(format!("{err:#}").contains("fails verification"),
                "{err:#}");
    }
}
