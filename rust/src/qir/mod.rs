//! QIR — the typed integer compute-graph IR of the deployed controller.
//!
//! The paper's pipeline ends in hardware: a QAT policy is lowered to an
//! integer-only datapath and synthesized to an Artix-7 (§2.3, §3.4).
//! QIR is that datapath as a first-class object: a [`QGraph`] of typed
//! ops — [`QOp::QuantizeInput`], [`QOp::MatVec`],
//! [`QOp::ThresholdRequant`], [`QOp::TanhLut`] — whose edges carry
//! explicit integer value types ([`EdgeTy`]: dimensions, value bounds,
//! quantization lattices). Every consumer of the integer semantics is a
//! backend over this one IR instead of re-interpreting the raw
//! [`IntPolicy`] struct:
//!
//! * [`interp::Interpreter`] — the reference executor
//!   (`crate::intinfer::IntEngine` stays the fast specialized executor
//!   and is pinned bit-identical to it by `rust/tests/qir.rs`),
//! * `crate::synth` — the FPGA costing/folding estimator consumes
//!   [`QGraph`] op metadata,
//! * [`emit_c`] / [`emit_verilog`] — render the graph as a
//!   self-contained integer-only C file or a Verilog module
//!   (`qcontrol emit`).
//!
//! The contract: [`lower`] turns an [`IntPolicy`] into a graph,
//! [`QGraph::verify`] checks the structural invariants **once** — dim
//! chaining, weight-lattice membership, per-row threshold monotonicity,
//! and accumulator-width safety (the worst case `cols × |w|max × |x|max`
//! must fit an `i32`, because every fast executor accumulates in `i32`)
//! — and backends may then assume a well-formed graph instead of each
//! asserting its own subset. Verification failures are descriptive
//! errors, never panics.

pub mod emit_c;
pub mod emit_verilog;
pub mod interp;
pub mod passes;

pub use emit_c::{emit_c, emit_c_registry, identifier, write_c, CEmitter,
                 RomShareReport};
pub use emit_verilog::{emit_verilog, write_verilog, VerilogEmitter};
pub use interp::{Interpret, Interpreter};
pub use passes::{prepare, CostEstimate, FuseTrivialRequant,
                 NarrowAccWidths, OptLevel, Pass, PassDelta, PassManager,
                 PassOutcome, PassReport, PruneDeadRows};

use anyhow::{bail, ensure, Result};

use crate::quant::export::IntPolicy;
use crate::quant::QRange;

/// Type of one edge of the compute graph: what values flow between two
/// ops. Integer edges carry exact inclusive value bounds plus (when the
/// edge is a quantization lattice rather than a raw accumulator) the
/// lattice description.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeTy {
    /// f32 values at the graph boundary (the normalized observation in,
    /// the tanh'd action out) — the only non-integer edges.
    F32 { dim: usize },
    /// Integer values in `[lo, hi]`; `lattice` is present when the edge
    /// is a quantization lattice (then `lo = qmin`, `hi = qmax`).
    Int {
        dim: usize,
        lo: i64,
        hi: i64,
        lattice: Option<QRange>,
    },
}

impl EdgeTy {
    /// A lattice-typed integer edge.
    pub fn lattice(dim: usize, r: QRange) -> EdgeTy {
        EdgeTy::Int {
            dim,
            lo: r.qmin as i64,
            hi: r.qmax as i64,
            lattice: Some(r),
        }
    }

    /// A symmetric accumulator edge `[-bound, bound]`.
    pub fn acc(dim: usize, bound: i64) -> EdgeTy {
        EdgeTy::Int { dim, lo: -bound, hi: bound, lattice: None }
    }

    pub fn dim(&self) -> usize {
        match *self {
            EdgeTy::F32 { dim } | EdgeTy::Int { dim, .. } => dim,
        }
    }

    /// Largest absolute value the edge can carry (0 for f32 edges).
    pub fn abs_max(&self) -> i64 {
        match *self {
            EdgeTy::F32 { .. } => 0,
            EdgeTy::Int { lo, hi, .. } => lo.abs().max(hi.abs()),
        }
    }

    /// Minimal two's-complement storage width for the edge's values
    /// (sign bit included when `lo < 0`); 0 for f32 edges. For a b-bit
    /// lattice edge this reproduces b exactly, for an accumulator edge
    /// the analytic `acc_bits` of the exporter.
    pub fn bits(&self) -> u32 {
        fn ubits(v: u64) -> u32 {
            64 - v.leading_zeros()
        }
        match *self {
            EdgeTy::F32 { .. } => 0,
            EdgeTy::Int { lo, hi, .. } => {
                if lo < 0 {
                    let pos = ubits(hi.max(0) as u64) + 1;
                    let neg = ubits(lo.unsigned_abs() - 1) + 1;
                    pos.max(neg)
                } else {
                    ubits(hi as u64).max(1)
                }
            }
        }
    }

    pub fn signed(&self) -> bool {
        matches!(*self, EdgeTy::Int { lo, .. } if lo < 0)
    }
}

/// Ops of the integer datapath, in the paper's §2.3 vocabulary.
#[derive(Clone, Debug, PartialEq)]
pub enum QOp {
    /// The single floating-point operation of the deployed controller:
    /// project the (already normalized) observation onto the input
    /// lattice with scale `s_in`.
    QuantizeInput { s_in: f32 },
    /// Integer matrix-vector product, `[rows, cols]` row-major lattice
    /// weights on the signed `w_bits` lattice, wide accumulator out.
    MatVec {
        rows: usize,
        cols: usize,
        w_bits: u32,
        w: Vec<i8>,
    },
    /// FINN-style threshold requantization of an accumulator vector onto
    /// a `levels`-point lattice: `out = qmin + #{k : T[row][k] <= acc}`,
    /// `[rows, levels-1]` row-major monotone thresholds (bias folded
    /// in). `acc_bits` is the declared accumulator width the hardware
    /// datapath provisions (drives the synthesis cost model).
    ThresholdRequant {
        levels: usize,
        acc_bits: u32,
        thresholds: Vec<i32>,
    },
    /// Terminal lookup of the output lattice through the tanh table —
    /// integer index in, IEEE-754 bit pattern out.
    TanhLut { lut: Vec<f32> },
}

impl QOp {
    pub fn name(&self) -> &'static str {
        match self {
            QOp::QuantizeInput { .. } => "QuantizeInput",
            QOp::MatVec { .. } => "MatVec",
            QOp::ThresholdRequant { .. } => "ThresholdRequant",
            QOp::TanhLut { .. } => "TanhLut",
        }
    }
}

/// The typed integer compute graph: a verified chain
/// `QuantizeInput → (MatVec → ThresholdRequant)+ → TanhLut` with
/// `edges[i]` the output type of `ops[i]` (the input of `ops[0]` is the
/// implicit `F32 { obs_dim }` boundary edge).
#[derive(Clone, Debug, PartialEq)]
pub struct QGraph {
    /// provenance label (artifact id, …) — used by the emitters
    pub name: String,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub ops: Vec<QOp>,
    pub edges: Vec<EdgeTy>,
}

/// Lower a deployable [`IntPolicy`] to its compute graph. Pure
/// restructuring — every number is carried over, nothing recomputed —
/// so `lower` cannot fail; call [`QGraph::verify`] before executing,
/// costing, or emitting the result.
pub fn lower(p: &IntPolicy) -> QGraph {
    let mut ops = vec![QOp::QuantizeInput { s_in: p.s_in }];
    let mut edges = vec![EdgeTy::lattice(p.obs_dim, p.in_range)];
    for l in &p.layers {
        ops.push(QOp::MatVec {
            rows: l.rows,
            cols: l.cols,
            w_bits: l.w_bits,
            w: l.w_int.clone(),
        });
        edges.push(EdgeTy::acc(l.rows, l.acc_abs_bound()));
        ops.push(QOp::ThresholdRequant {
            levels: l.out_range.levels(),
            acc_bits: l.acc_bits,
            thresholds: l.thresholds.clone(),
        });
        edges.push(EdgeTy::lattice(l.rows, l.out_range));
    }
    ops.push(QOp::TanhLut { lut: p.tanh_lut.clone() });
    edges.push(EdgeTy::F32 { dim: p.act_dim });
    QGraph {
        name: "qpol".to_string(),
        obs_dim: p.obs_dim,
        act_dim: p.act_dim,
        ops,
        edges,
    }
}

impl QGraph {
    pub fn with_name(mut self, name: impl Into<String>) -> QGraph {
        self.name = name.into();
        self
    }

    /// Input edge type of op `i`.
    fn in_edge(&self, i: usize) -> EdgeTy {
        if i == 0 {
            EdgeTy::F32 { dim: self.obs_dim }
        } else {
            self.edges[i - 1]
        }
    }

    /// One-line structural summary ("QuantizeInput(5) → MatVec 16x5 w4 →
    /// …") for logs and emitted-file headers.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .ops
            .iter()
            .map(|op| match op {
                QOp::QuantizeInput { .. } => {
                    format!("QuantizeInput({})", self.obs_dim)
                }
                QOp::MatVec { rows, cols, w_bits, .. } => {
                    format!("MatVec {rows}x{cols} w{w_bits}")
                }
                QOp::ThresholdRequant { levels, acc_bits, .. } => {
                    format!("ThresholdRequant({levels} lv, acc \
                             {acc_bits}b)")
                }
                QOp::TanhLut { lut } => format!("TanhLut({})", lut.len()),
            })
            .collect();
        parts.join(" -> ")
    }

    /// Check every structural invariant of the graph once, so backends
    /// (interpreter, synthesis estimator, emitters) can assume a
    /// well-formed datapath. Errors are descriptive and name the
    /// offending op; this never panics.
    ///
    /// Invariants:
    /// * canonical shape `QuantizeInput (MatVec ThresholdRequant)+
    ///   TanhLut`, with one output edge type per op;
    /// * dimension chaining: each op's input dim equals the previous
    ///   op's output dim (`cols` for MatVec), boundary dims match
    ///   `obs_dim`/`act_dim`;
    /// * weights live on the signed `w_bits` lattice;
    /// * **accumulator-width safety**: the worst-case magnitude
    ///   `cols × |w|max × |x|max` of every MatVec fits an `i32` — the
    ///   fast executors (`IntEngine`, the emitted C, the Verilog
    ///   datapath) accumulate at finite width, so a wider graph is
    ///   rejected here instead of silently wrapping there;
    /// * every accumulator edge covers the exact interval-propagated
    ///   `[lo, hi]` of its MatVec (exact, not the crude symmetric
    ///   bound, so the narrowed edges the optimizer declares verify
    ///   while anything tighter than reality is rejected);
    /// * the declared `acc_bits` of each requant covers its input edge;
    /// * thresholds: `rows × (levels-1)` of them, monotone
    ///   nondecreasing per row;
    /// * the tanh LUT is finite and exactly covers the output lattice.
    pub fn verify(&self) -> Result<()> {
        ensure!(!self.ops.is_empty(), "empty graph");
        ensure!(self.ops.len() == self.edges.len(),
                "{} ops but {} edge types", self.ops.len(),
                self.edges.len());
        ensure!(self.ops.len() >= 4 && self.ops.len() % 2 == 0,
                "graph has {} ops, expected QuantizeInput + N x (MatVec, \
                 ThresholdRequant) + TanhLut", self.ops.len());
        ensure!(self.obs_dim >= 1 && self.act_dim >= 1,
                "degenerate boundary dims {}x{}", self.obs_dim,
                self.act_dim);

        for (i, op) in self.ops.iter().enumerate() {
            let inp = self.in_edge(i);
            let out = self.edges[i];
            let last = i + 1 == self.ops.len();
            match op {
                QOp::QuantizeInput { s_in } => {
                    ensure!(i == 0,
                            "op {i}: QuantizeInput only legal at the \
                             input boundary");
                    ensure!(s_in.is_finite() && *s_in > 0.0,
                            "op {i}: input scale {s_in} not a positive \
                             finite f32");
                    let EdgeTy::Int { dim, lo, hi, lattice: Some(r) } =
                        out
                    else {
                        bail!("op {i}: QuantizeInput must emit an \
                               integer lattice edge, got {out:?}");
                    };
                    ensure!(dim == self.obs_dim,
                            "op {i}: quantizer dim {dim} != obs_dim {}",
                            self.obs_dim);
                    ensure!(lo == r.qmin as i64 && hi == r.qmax as i64,
                            "op {i}: lattice edge bounds [{lo}, {hi}] \
                             disagree with its QRange {r:?}");
                    ensure!(r.qmax > r.qmin && r.qs >= 1,
                            "op {i}: degenerate input lattice {r:?}");
                }
                QOp::MatVec { rows, cols, w_bits, w } => {
                    ensure!(i % 2 == 1 && !last,
                            "op {i}: MatVec out of place (canonical \
                             chain is QuantizeInput, then MatVec/\
                             ThresholdRequant pairs, then TanhLut)");
                    ensure!(*rows >= 1 && *cols >= 1,
                            "op {i}: degenerate MatVec {rows}x{cols}");
                    let EdgeTy::Int { dim: in_dim, lo: in_lo,
                                      hi: in_hi, .. } = inp
                    else {
                        bail!("op {i}: MatVec input must be an integer \
                               edge, got {inp:?}");
                    };
                    ensure!(in_dim == *cols,
                            "op {i}: MatVec cols {cols} != input dim \
                             {in_dim} (dim chain broken)");
                    ensure!(w.len() == rows * cols,
                            "op {i}: {} weights for a {rows}x{cols} \
                             MatVec", w.len());
                    ensure!((1..=8).contains(w_bits),
                            "op {i}: w_bits {w_bits} outside 1..=8 (i8 \
                             lattice storage)");
                    let wr = QRange::new(*w_bits, true);
                    if let Some(bad) = w
                        .iter()
                        .find(|&&v| (v as i32) < wr.qmin
                              || (v as i32) > wr.qmax)
                    {
                        bail!("op {i}: weight {bad} off the signed \
                               {w_bits}-bit lattice [{}, {}]", wr.qmin,
                              wr.qmax);
                    }
                    // --- accumulator-width safety ---------------------
                    // The fast executors accumulate in i32 (IntEngine's
                    // hot loop, the emitted C datapath); reject any
                    // graph whose worst case could wrap there. i128
                    // keeps the bound computation itself overflow-free.
                    let wmax = w
                        .iter()
                        .fold(0i64, |m, &v| m.max((v as i64).abs()));
                    let xmax = inp.abs_max();
                    let bound =
                        *cols as i128 * wmax as i128 * xmax as i128;
                    ensure!(bound <= i32::MAX as i128,
                            "op {i}: worst-case accumulator {bound} \
                             (cols {cols} x |w|max {wmax} x |x|max \
                             {xmax}) exceeds i32 — the integer engines \
                             accumulate at 32 bits");
                    let EdgeTy::Int { dim: out_dim, lo, hi, .. } = out
                    else {
                        bail!("op {i}: MatVec must emit an integer \
                               accumulator edge, got {out:?}");
                    };
                    ensure!(out_dim == *rows,
                            "op {i}: accumulator dim {out_dim} != rows \
                             {rows}");
                    // Exact interval-propagated covering check (safe in
                    // i64 only *after* the crude bound above passed):
                    // the optimizer's narrow pass declares exact edges,
                    // so the covering requirement must be exact too —
                    // the crude symmetric bound would reject them.
                    let (exact_lo, exact_hi) =
                        passes::matvec_interval(w, *rows, *cols, in_lo,
                                                in_hi);
                    ensure!(lo <= exact_lo && hi >= exact_hi,
                            "op {i}: accumulator edge [{lo}, {hi}] does \
                             not cover the worst case [{exact_lo}, \
                             {exact_hi}]");
                }
                QOp::ThresholdRequant { levels, acc_bits, thresholds } => {
                    ensure!(i % 2 == 0 && i >= 2 && !last,
                            "op {i}: ThresholdRequant out of place \
                             (must follow a MatVec)");
                    ensure!(*levels >= 2,
                            "op {i}: requant to {levels} level(s)");
                    let EdgeTy::Int { dim, .. } = inp else {
                        bail!("op {i}: requant input must be an integer \
                               edge, got {inp:?}");
                    };
                    ensure!((1..=64).contains(acc_bits),
                            "op {i}: acc_bits {acc_bits} outside 1..=64");
                    ensure!(*acc_bits >= inp.bits(),
                            "op {i}: declared acc_bits {acc_bits} \
                             narrower than the {} bits its input edge \
                             needs", inp.bits());
                    ensure!(thresholds.len() == dim * (levels - 1),
                            "op {i}: {} thresholds for {dim} rows x {} \
                             cutpoints", thresholds.len(), levels - 1);
                    for row in 0..dim {
                        let t =
                            &thresholds[row * (levels - 1)
                                ..(row + 1) * (levels - 1)];
                        if let Some(k) =
                            t.windows(2).position(|w| w[0] > w[1])
                        {
                            bail!("op {i}: non-monotone thresholds in \
                                   row {row} at cutpoint {k} ({} > {})",
                                  t[k], t[k + 1]);
                        }
                    }
                    let EdgeTy::Int { dim: out_dim, lo, hi,
                                      lattice: Some(r) } = out
                    else {
                        bail!("op {i}: requant must emit an integer \
                               lattice edge, got {out:?}");
                    };
                    ensure!(out_dim == dim,
                            "op {i}: requant changed dim {dim} -> \
                             {out_dim}");
                    ensure!(r.levels() == *levels,
                            "op {i}: output lattice has {} levels, op \
                             declares {levels}", r.levels());
                    ensure!(lo == r.qmin as i64 && hi == r.qmax as i64,
                            "op {i}: lattice edge bounds [{lo}, {hi}] \
                             disagree with its QRange {r:?}");
                }
                QOp::TanhLut { lut } => {
                    ensure!(last,
                            "op {i}: TanhLut only legal at the output \
                             boundary");
                    let EdgeTy::Int { dim, lattice: Some(r), .. } = inp
                    else {
                        bail!("op {i}: TanhLut input must be an integer \
                               lattice edge, got {inp:?}");
                    };
                    ensure!(dim == self.act_dim,
                            "op {i}: output dim {dim} != act_dim {}",
                            self.act_dim);
                    ensure!(lut.len() == r.levels(),
                            "op {i}: tanh LUT of {} entries over a {}-\
                             level lattice", lut.len(), r.levels());
                    ensure!(lut.iter().all(|v| v.is_finite()),
                            "op {i}: non-finite tanh LUT entry");
                    let boundary = EdgeTy::F32 { dim: self.act_dim };
                    ensure!(out == boundary,
                            "op {i}: TanhLut must emit the f32 action \
                             boundary, got {out:?}");
                }
            }
        }
        Ok(())
    }

    /// Flat per-layer view (fused MatVec + ThresholdRequant) of a graph
    /// in canonical form — the shared substrate of the synthesis
    /// geometry pass and the emitters. Call [`QGraph::verify`] first;
    /// this re-checks only the shape it needs to slice safely.
    pub fn layers(&self) -> Result<Vec<LayerView<'_>>> {
        let mut out = Vec::new();
        let mut i = 1;
        while i + 1 < self.ops.len() {
            let (QOp::MatVec { rows, cols, w_bits, w },
                 QOp::ThresholdRequant { levels, acc_bits, thresholds }) =
                (&self.ops[i], &self.ops[i + 1])
            else {
                bail!("op {i}: graph not in canonical \
                       MatVec/ThresholdRequant form (run verify)");
            };
            let EdgeTy::Int { lattice: Some(out_range), .. } =
                self.edges[i + 1]
            else {
                bail!("op {}: requant output is not a lattice edge",
                      i + 1);
            };
            out.push(LayerView {
                rows: *rows,
                cols: *cols,
                w_bits: *w_bits,
                w: w.as_slice(),
                levels: *levels,
                acc_bits: *acc_bits,
                thresholds: thresholds.as_slice(),
                in_edge: self.in_edge(i),
                acc_edge: self.edges[i],
                out_range,
            });
            i += 2;
        }
        ensure!(!out.is_empty(), "graph has no MatVec layers");
        Ok(out)
    }

    /// The input quantizer boundary: `(s_in, input lattice)`.
    pub fn input_quantizer(&self) -> Result<(f32, QRange)> {
        match (self.ops.first(), self.edges.first()) {
            (Some(QOp::QuantizeInput { s_in }),
             Some(EdgeTy::Int { lattice: Some(r), .. })) => {
                Ok((*s_in, *r))
            }
            _ => bail!("graph does not start with QuantizeInput"),
        }
    }

    /// The terminal tanh LUT and the lattice it indexes.
    pub fn tanh(&self) -> Result<(&[f32], QRange)> {
        let n = self.ops.len();
        ensure!(n >= 2 && self.edges.len() == n,
                "graph too short for a TanhLut boundary");
        let Some(QOp::TanhLut { lut }) = self.ops.last() else {
            bail!("graph does not end with TanhLut");
        };
        let EdgeTy::Int { lattice: Some(r), .. } = self.edges[n - 2]
        else {
            bail!("TanhLut input is not a lattice edge");
        };
        Ok((lut.as_slice(), r))
    }

    /// The per-layer width vector of the datapath — the input lattice
    /// width plus each layer's (weight width, output-lattice width) —
    /// as a [`LayerBits`] allocation. Derived entirely from the typed
    /// edges, so it reflects what the graph *is*, declared or not; the
    /// emitters stamp its canonical string into generated file headers
    /// so synthesized datapaths are self-describing.
    pub fn layer_bits(&self) -> Result<crate::quant::LayerBits> {
        let (_, in_r) = self.input_quantizer()?;
        let layers = self
            .layers()?
            .iter()
            .map(|v| (v.w_bits, v.out_range.bits()))
            .collect();
        Ok(crate::quant::LayerBits { b_in: in_r.bits(), layers })
    }

    /// Largest integer vector dim flowing through the graph (scratch
    /// sizing for executors and the emitted C).
    pub fn max_int_dim(&self) -> usize {
        self.edges
            .iter()
            .filter(|e| matches!(e, EdgeTy::Int { .. }))
            .map(|e| e.dim())
            .max()
            .unwrap_or(1)
            .max(self.obs_dim)
    }
}

/// One fused MatVec + ThresholdRequant layer of a canonical graph.
#[derive(Clone, Copy, Debug)]
pub struct LayerView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub w_bits: u32,
    pub w: &'a [i8],
    pub levels: usize,
    pub acc_bits: u32,
    pub thresholds: &'a [i32],
    /// lattice edge feeding the MatVec
    pub in_edge: EdgeTy,
    /// accumulator edge between the MatVec and the requant
    pub acc_edge: EdgeTy,
    /// lattice the requant lands on
    pub out_range: QRange,
}

/// A consumer of verified graphs: reference execution, synthesis
/// costing, code emission. `compile` must accept any graph that passes
/// [`QGraph::verify`] (implementations call it once up front), so every
/// future op or backend plugs in at this one seam.
pub trait QirBackend {
    type Output;
    fn name(&self) -> &'static str;
    fn compile(&self, g: &QGraph) -> Result<Self::Output>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitCfg;
    use crate::util::testkit;

    fn toy_graph() -> QGraph {
        lower(&testkit::toy_policy(3, 5, 8, 2, BitCfg::new(4, 3, 8)))
    }

    #[test]
    fn lowered_graph_verifies() {
        let g = toy_graph();
        g.verify().unwrap();
        assert_eq!(g.ops.len(), 2 + 2 * 3);
        assert_eq!(g.layers().unwrap().len(), 3);
        let (s_in, r) = g.input_quantizer().unwrap();
        assert!(s_in > 0.0);
        assert_eq!(r, QRange::new(4, true));
        let (lut, out_r) = g.tanh().unwrap();
        assert_eq!(lut.len(), out_r.levels());
    }

    #[test]
    fn edge_bits_reproduce_lattice_widths() {
        for b in 1..=16u32 {
            assert_eq!(EdgeTy::lattice(1, QRange::new(b, true)).bits(), b);
            assert_eq!(EdgeTy::lattice(1, QRange::new(b, false)).bits(),
                       b);
        }
        // accumulator edges reproduce the exporter's analytic acc_bits
        for bound in [1i64, 2, 3, 4, 7, 8, 100, 32385] {
            let want = 64 - (bound as u64).leading_zeros() + 1;
            assert_eq!(EdgeTy::acc(1, bound).bits(), want, "bound {bound}");
        }
    }

    #[test]
    fn layer_views_carry_the_exporter_metadata() {
        let p = testkit::toy_policy(7, 4, 6, 2, BitCfg::new(5, 3, 6));
        let g = lower(&p);
        g.verify().unwrap();
        let views = g.layers().unwrap();
        for (v, l) in views.iter().zip(&p.layers) {
            assert_eq!((v.rows, v.cols), (l.rows, l.cols));
            assert_eq!(v.w_bits, l.w_bits);
            assert_eq!(v.acc_bits, l.acc_bits);
            assert_eq!(v.w, &l.w_int[..]);
            assert_eq!(v.thresholds, &l.thresholds[..]);
            assert_eq!(v.out_range, l.out_range);
            assert_eq!(v.levels, l.out_range.levels());
        }
        // edge storage widths reproduce the BitCfg
        assert_eq!(views[0].in_edge.bits(), 5);
        assert_eq!(views[1].in_edge.bits(), 3);
        assert_eq!(EdgeTy::lattice(1, views[2].out_range).bits(), 6);
    }

    #[test]
    fn summary_names_every_op() {
        let s = toy_graph().summary();
        for part in ["QuantizeInput(5)", "MatVec 8x5", "ThresholdRequant",
                     "TanhLut"] {
            assert!(s.contains(part), "{s}");
        }
    }
}
