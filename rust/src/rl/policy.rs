//! Flat-parameter helpers on the rust side: initialization (mirroring
//! `params.py::init_flat`'s distributions) and extraction of the actor
//! tensors for the quantization/export path.

use anyhow::Result;

use crate::quant::fakequant::PolicyTensors;
use crate::runtime::ParamSpec;
use crate::util::rng::Rng;

/// Initialize a flat parameter vector: PyTorch-default kaiming-uniform
/// (±1/√fan_in) for linear layers, 1.0 for learned scales, 0 for log_alpha,
/// targets copied from their online sources.
///
/// The *distribution* matches the python reference; the draws come from the
/// rust RNG (bit-identical parity with python is not required — golden
/// tests pin the math, not the seeds).
pub fn init_flat(spec: &ParamSpec, rng: &mut Rng) -> Vec<f32> {
    let mut flat = vec![0.0f32; spec.n_params];
    for e in &spec.entries {
        let seg = e.offset..e.offset + e.size;
        if e.group == "scale" {
            flat[seg].fill(1.0);
        } else if e.name.ends_with(".w") {
            let fan_in = *e.shape.get(1).unwrap_or(&1) as f64;
            let bound = 1.0 / fan_in.sqrt();
            for x in &mut flat[seg] {
                *x = rng.uniform_in(-bound, bound) as f32;
            }
        } else if e.name.ends_with(".b") {
            let w = spec
                .find(&format!("{}w", &e.name[..e.name.len() - 1]))
                .expect("bias without matching weight");
            let bound = 1.0 / (*w.shape.get(1).unwrap_or(&1) as f64).sqrt();
            for x in &mut flat[seg] {
                *x = rng.uniform_in(-bound, bound) as f32;
            }
        }
        // log_alpha and anything else: zero
    }
    // targets start as exact copies
    for e in &spec.entries {
        if let Some(src_name) = e.name.strip_prefix("tgt_") {
            if let Ok(src) = spec.find(src_name) {
                let (a, b) = (src.offset, e.offset);
                for i in 0..e.size {
                    flat[b + i] = flat[a + i];
                }
            }
        }
    }
    flat
}

/// Borrow the actor tensors out of a flat vector (for `IntPolicy` export
/// and the fake-quant mirror).
pub fn extract_tensors<'a>(spec: &ParamSpec, flat: &'a [f32],
                           obs_dim: usize, hidden: usize, act_dim: usize)
                           -> Result<PolicyTensors<'a>> {
    let t = PolicyTensors {
        obs_dim,
        hidden,
        act_dim,
        fc1_w: spec.slice(flat, "actor.fc1.w")?,
        fc1_b: spec.slice(flat, "actor.fc1.b")?,
        fc2_w: spec.slice(flat, "actor.fc2.w")?,
        fc2_b: spec.slice(flat, "actor.fc2.b")?,
        mean_w: spec.slice(flat, "actor.mean.w")?,
        mean_b: spec.slice(flat, "actor.mean.b")?,
        s_in: spec.scalar(flat, "actor.s_in")?,
        s_h1: spec.scalar(flat, "actor.s_h1")?,
        s_h2: spec.scalar(flat, "actor.s_h2")?,
        s_out: spec.scalar(flat, "actor.s_out")?,
    };
    t.validate();
    Ok(t)
}

/// Checkpoint a flat vector + normalizer to a simple binary format
/// (little-endian f32s with a JSON header line).
pub fn save_checkpoint(path: &std::path::Path, flat: &[f32],
                       norm_state: &(Vec<f64>, Vec<f64>),
                       meta: &crate::util::json::Json) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let header = meta.to_string();
    writeln!(f, "{header}")?;
    writeln!(f, "{} {} {}", flat.len(), norm_state.0.len(),
             norm_state.1.len())?;
    for &x in flat {
        f.write_all(&x.to_le_bytes())?;
    }
    for &x in &norm_state.0 {
        f.write_all(&(x as f32).to_le_bytes())?;
    }
    for &x in &norm_state.1 {
        f.write_all(&(x as f32).to_le_bytes())?;
    }
    Ok(())
}

/// Load a checkpoint written by [`save_checkpoint`].
pub fn load_checkpoint(path: &std::path::Path)
                       -> Result<(crate::util::json::Json, Vec<f32>,
                                  Vec<f64>, Vec<f64>)> {
    use std::io::{BufRead, Read};
    let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let meta = crate::util::json::parse(header.trim())?;
    let mut counts = String::new();
    r.read_line(&mut counts)?;
    let ns: Vec<usize> = counts
        .trim()
        .split(' ')
        .map(|s| s.parse())
        .collect::<std::result::Result<_, _>>()?;
    anyhow::ensure!(ns.len() == 3, "bad checkpoint counts line");
    let mut read_f32s = |n: usize| -> Result<Vec<f32>> {
        let mut buf = vec![0u8; 4 * n];
        r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    };
    let flat = read_f32s(ns[0])?;
    let mean = read_f32s(ns[1])?.iter().map(|&x| x as f64).collect();
    let var = read_f32s(ns[2])?.iter().map(|&x| x as f64).collect();
    Ok((meta, flat, mean, var))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::SpecEntry;
    use crate::util::json::Json;

    fn toy_spec() -> ParamSpec {
        let entries = vec![
            SpecEntry { name: "actor.fc1.w".into(), shape: vec![4, 3],
                        offset: 0, size: 12, group: "actor".into() },
            SpecEntry { name: "actor.fc1.b".into(), shape: vec![4],
                        offset: 12, size: 4, group: "actor".into() },
            SpecEntry { name: "actor.s_in".into(), shape: vec![],
                        offset: 16, size: 1, group: "scale".into() },
            SpecEntry { name: "log_alpha".into(), shape: vec![],
                        offset: 17, size: 1, group: "alpha".into() },
            SpecEntry { name: "tgt_actor.fc1.w".into(), shape: vec![4, 3],
                        offset: 18, size: 12, group: "target".into() },
        ];
        ParamSpec { n_params: 30, entries }
    }

    #[test]
    fn init_distributions() {
        let spec = toy_spec();
        let mut rng = Rng::new(0);
        let flat = init_flat(&spec, &mut rng);
        let bound = 1.0 / 3.0f32.sqrt();
        assert!(flat[..12].iter().all(|x| x.abs() <= bound));
        assert!(flat[..12].iter().any(|x| x.abs() > 1e-3));
        assert_eq!(flat[16], 1.0); // scale
        assert_eq!(flat[17], 0.0); // log_alpha
        assert_eq!(&flat[18..30], &flat[0..12]); // target copy
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("qcontrol_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.ckpt");
        let flat = vec![1.0f32, -2.5, 3.25];
        let norm = (vec![0.5f64], vec![2.0f64]);
        let meta = Json::obj(vec![("env", Json::str("pendulum"))]);
        save_checkpoint(&path, &flat, &norm, &meta).unwrap();
        let (m2, f2, mean, var) = load_checkpoint(&path).unwrap();
        assert_eq!(f2, flat);
        assert_eq!(mean, vec![0.5]);
        assert_eq!(var, vec![2.0]);
        assert_eq!(m2.get("env").unwrap().as_str().unwrap(), "pendulum");
    }
}
