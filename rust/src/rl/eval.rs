//! Deterministic policy evaluation under composable [`Scenario`]s,
//! vectorized through [`VecEnv`] and driven by the unified
//! [`PolicyBackend`] trait.
//!
//! The historical single-knob `noise_std` rollout is gone: an
//! evaluation condition is now a full scenario
//! (`hopper+obsnoise:0.05+delay:2`), built as a wrapper stack over the
//! base env, and rollouts run as a lockstep episode pool that gathers
//! live observations into one `infer_batch` block per step — the same
//! batched inference path the serving subsystem exercises. Results are
//! bit-identical at any pool size (see [`VecEnv`]), and the bare
//! scenario at pool 1 reproduces the classic serial rollout exactly.
//!
//! The interchangeable execution paths — whose agreement is itself a
//! validation of the deployment chain — are resolved *once* into a
//! `Box<dyn PolicyBackend>` before the rollout:
//!
//! * `pjrt`      — the AOT `*_fwd_*` artifact (L2 graph incl. the Pallas
//!                 kernel path), wrapped in [`PjrtBackend`],
//! * `fakequant` — the pure-rust fake-quant mirror
//!                 ([`crate::policy::FakeQuantBackend`]),
//! * `fp32`      — the plain FP32 reference
//!                 ([`crate::policy::Fp32Backend`]),
//! * `int`       — the integer-only engine (`intinfer`), i.e. exactly
//!                 what the FPGA executes.
//!
//! Perturbation placement: the wrapper stack sits **above** a frozen
//! normalization layer, so observation atoms act on the normalized
//! state the policy consumes (paper §3.3: ŝ = norm(s) + ε), and action
//! atoms act on the policy's [-1,1] commands before the env's clamped
//! step boundary.

use anyhow::Result;

use super::{fwd_hyper, policy::extract_tensors, Algo};
use crate::envs::{self, wrappers, Scenario, VecEnv};
use crate::intinfer::IntEngine;
use crate::policy::{FakeQuantBackend, Fp32Backend, PolicyBackend,
                    PolicyDescriptor};
use crate::quant::export::IntPolicy;
use crate::quant::fakequant::PolicyTensors;
use crate::quant::{BitCfg, LayerBits};
use crate::runtime::{Exe, Runtime};
use crate::util::stats::{self, ObsNormalizer};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalBackend {
    Pjrt,
    FakeQuant,
    Fp32,
    Integer,
}

impl EvalBackend {
    pub fn parse(s: &str) -> Result<EvalBackend> {
        Ok(match s {
            "pjrt" => EvalBackend::Pjrt,
            "fakequant" => EvalBackend::FakeQuant,
            "fp32" => EvalBackend::Fp32,
            "integer" | "int" => EvalBackend::Integer,
            _ => anyhow::bail!(
                "unknown backend `{s}` (pjrt|fakequant|fp32|int|integer)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EvalBackend::Pjrt => "pjrt",
            EvalBackend::FakeQuant => "fakequant",
            EvalBackend::Fp32 => "fp32",
            EvalBackend::Integer => "int",
        }
    }
}

/// Episode-pool width used when the caller doesn't pin one. Results are
/// pool-size-invariant, so this is purely a dispatch-amortization knob.
pub const DEFAULT_POOL: usize = 8;

#[derive(Clone, Debug)]
pub struct EvalOpts {
    pub algo: Algo,
    /// What to evaluate on: env + perturbation stack
    /// (`Scenario::bare(env)` for the clean condition).
    pub scenario: Scenario,
    pub hidden: usize,
    pub bits: BitCfg,
    pub quant_on: bool,
    pub episodes: usize,
    pub seed: u64,
    pub backend: EvalBackend,
    /// Optional heterogeneous per-layer allocation. Only the `Integer`
    /// backend consumes it (the integer engine is the one path whose
    /// layer geometry is free per layer); when set, `bits` must be its
    /// envelope — [`Trial::with_lbits`](crate::experiment::Trial)
    /// maintains that invariant for executor-driven evals.
    pub lbits: Option<LayerBits>,
}

impl EvalOpts {
    /// The environment name (from the scenario).
    pub fn env(&self) -> &str {
        &self.scenario.env
    }
}

/// Resolve the requested execution path into a trait object over the
/// checkpoint's tensors. `flat` must outlive the backend (the PJRT path
/// borrows it as a graph input).
pub fn make_backend<'a>(rt: &Runtime, opts: &EvalOpts, flat: &'a [f32],
                        tensors: &PolicyTensors) -> Result<Box<dyn PolicyBackend + 'a>> {
    Ok(match opts.backend {
        EvalBackend::Pjrt => {
            let exe = rt.exe_for(opts.algo.name(), "fwd", opts.env(),
                                 opts.hidden, Some(1))?;
            let hyper = fwd_hyper(rt, opts.bits, opts.quant_on);
            Box::new(PjrtBackend {
                exe,
                flat,
                hyper,
                obs_dim: tensors.obs_dim,
                act_dim: tensors.act_dim,
                hidden: tensors.hidden,
            })
        }
        // the fake-quant mirror with the quant gate off *is* FP32
        EvalBackend::FakeQuant if opts.quant_on => {
            Box::new(FakeQuantBackend::new(tensors, opts.bits))
        }
        EvalBackend::FakeQuant | EvalBackend::Fp32 => {
            Box::new(Fp32Backend::new(tensors))
        }
        EvalBackend::Integer => {
            anyhow::ensure!(opts.quant_on,
                            "integer backend requires a quantized policy");
            let policy = match &opts.lbits {
                Some(lb) => IntPolicy::from_tensors_mixed(tensors, lb)?,
                None => IntPolicy::from_tensors(tensors, opts.bits),
            };
            // the shared lower → optimize → verify → compile path gates
            // the i32 engine behind the IR invariants (notably
            // accumulator-width safety) exactly like artifact loading
            Box::new(IntEngine::optimized(policy)?)
        }
    })
}

/// Roll out the deterministic policy; returns (mean, std) of episode
/// returns.
pub fn evaluate(rt: &Runtime, opts: &EvalOpts, flat: &[f32],
                norm: &ObsNormalizer) -> Result<(f64, f64)> {
    let returns = evaluate_returns(rt, opts, flat, norm)?;
    Ok((stats::mean(&returns), stats::std(&returns)))
}

/// Full per-episode returns (for robustness bands and selection rules),
/// at the default pool width.
pub fn evaluate_returns(rt: &Runtime, opts: &EvalOpts, flat: &[f32],
                        norm: &ObsNormalizer) -> Result<Vec<f64>> {
    evaluate_returns_pooled(rt, opts, flat, norm,
                            DEFAULT_POOL.min(opts.episodes.max(1)))
}

/// Per-episode returns with a pinned episode-pool width. The pool is a
/// throughput knob only: any width yields bit-identical returns.
pub fn evaluate_returns_pooled(rt: &Runtime, opts: &EvalOpts,
                               flat: &[f32], norm: &ObsNormalizer,
                               pool: usize) -> Result<Vec<f64>> {
    // probe dims once, off the bare env
    let probe = envs::make(opts.env())?;
    let (obs_dim, act_dim) = (probe.obs_dim(), probe.act_dim());
    drop(probe);

    let spec = rt
        .manifest
        .specs
        .get(&format!("{}_{}_h{}", opts.algo.name(), opts.env(),
                      opts.hidden))
        .ok_or_else(|| anyhow::anyhow!("no spec for eval config"))?;
    let tensors = extract_tensors(spec, flat, obs_dim, opts.hidden,
                                  act_dim)?;
    let mut backend = make_backend(rt, opts, flat, &tensors)?;

    let mut venv = VecEnv::new(|| {
        let base = envs::make(opts.env())?;
        // scenario atoms stack above the frozen normalization layer
        Ok(opts.scenario.apply(wrappers::Normalize::wrap(base,
                                                         norm.clone())))
    }, pool)?;
    venv.rollout_returns(&mut *backend, opts.episodes, opts.seed)
}

/// The AOT-compiled forward graph behind the unified trait: runs the
/// batch-1 `*_fwd_*` executable row by row.
pub struct PjrtBackend<'a> {
    exe: std::sync::Arc<Exe>,
    flat: &'a [f32],
    hyper: Vec<f32>,
    obs_dim: usize,
    act_dim: usize,
    hidden: usize,
}

impl PolicyBackend for PjrtBackend<'_> {
    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.act_dim
    }

    fn infer_batch(&mut self, obs: &[f32], actions_out: &mut [f32])
                   -> Result<()> {
        crate::policy::check_block(obs, actions_out, self.obs_dim,
                                   self.act_dim)?;
        for (x, out) in obs
            .chunks_exact(self.obs_dim)
            .zip(actions_out.chunks_exact_mut(self.act_dim))
        {
            let res = self.exe.run_f32(&[self.flat, x, &self.hyper])?;
            anyhow::ensure!(res[0].len() == self.act_dim,
                            "fwd graph returned {} values, expected {}",
                            res[0].len(), self.act_dim);
            out.copy_from_slice(&res[0]);
        }
        Ok(())
    }

    fn macs(&self) -> u64 {
        crate::policy::mlp_macs(self.obs_dim, self.hidden, self.act_dim)
    }

    fn descriptor(&self) -> PolicyDescriptor {
        PolicyDescriptor {
            id: self.exe.meta.name.clone(),
            kind: "pjrt",
            obs_dim: self.obs_dim,
            act_dim: self.act_dim,
            hidden: self.hidden,
            bits: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_accepts_every_documented_token() {
        assert_eq!(EvalBackend::parse("pjrt").unwrap(), EvalBackend::Pjrt);
        assert_eq!(EvalBackend::parse("fakequant").unwrap(),
                   EvalBackend::FakeQuant);
        assert_eq!(EvalBackend::parse("fp32").unwrap(), EvalBackend::Fp32);
        // both spellings of the integer engine parse…
        assert_eq!(EvalBackend::parse("int").unwrap(),
                   EvalBackend::Integer);
        assert_eq!(EvalBackend::parse("integer").unwrap(),
                   EvalBackend::Integer);
        // …and the error text names every accepted token
        let err = EvalBackend::parse("tpu").unwrap_err().to_string();
        for tok in ["pjrt", "fakequant", "fp32", "int", "integer"] {
            assert!(err.contains(tok), "`{err}` missing `{tok}`");
        }
    }

}
