//! Deterministic policy evaluation: rollouts, input-noise injection
//! (Fig. 3), and three interchangeable policy backends whose agreement is
//! itself a validation of the deployment chain:
//!
//! * `Pjrt`      — the AOT `*_fwd_*` artifact (L2 graph incl. the Pallas
//!                 kernel path),
//! * `FakeQuant` — the pure-rust fake-quant mirror (`quant::fakequant`),
//! * `Integer`   — the integer-only engine (`intinfer`), i.e. exactly what
//!                 the FPGA executes.

use anyhow::Result;

use super::{fwd_hyper, policy::extract_tensors, Algo};
use crate::envs;
use crate::intinfer::IntEngine;
use crate::quant::export::IntPolicy;
use crate::quant::{fakequant, BitCfg};
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::util::stats::{self, ObsNormalizer};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalBackend {
    Pjrt,
    FakeQuant,
    Integer,
}

impl EvalBackend {
    pub fn parse(s: &str) -> Result<EvalBackend> {
        Ok(match s {
            "pjrt" => EvalBackend::Pjrt,
            "fakequant" => EvalBackend::FakeQuant,
            "integer" | "int" => EvalBackend::Integer,
            _ => anyhow::bail!("unknown backend `{s}` (pjrt|fakequant|int)"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct EvalOpts {
    pub algo: Algo,
    pub env: String,
    pub hidden: usize,
    pub bits: BitCfg,
    pub quant_on: bool,
    pub episodes: usize,
    /// i.i.d. Gaussian noise added to the *normalized* observation
    /// (paper §3.3): ŝ = norm(s) + ε, ε ~ N(0, σ²)
    pub noise_std: f64,
    pub seed: u64,
    pub backend: EvalBackend,
}

/// Roll out the deterministic policy; returns (mean, std) of episode
/// returns.
pub fn evaluate(rt: &Runtime, opts: &EvalOpts, flat: &[f32],
                norm: &ObsNormalizer) -> Result<(f64, f64)> {
    let returns = evaluate_returns(rt, opts, flat, norm)?;
    Ok((stats::mean(&returns), stats::std(&returns)))
}

/// Full per-episode returns (for robustness bands and selection rules).
pub fn evaluate_returns(rt: &Runtime, opts: &EvalOpts, flat: &[f32],
                        norm: &ObsNormalizer) -> Result<Vec<f64>> {
    let mut env = envs::make(&opts.env)?;
    let (obs_dim, act_dim) = (env.obs_dim(), env.act_dim());
    let mut rng = Rng::new(opts.seed);

    // backend setup
    let exe_fwd = match opts.backend {
        EvalBackend::Pjrt => Some(rt.exe_for(opts.algo.name(), "fwd",
                                             &opts.env, opts.hidden,
                                             Some(1))?),
        _ => None,
    };
    let spec = rt
        .manifest
        .specs
        .get(&format!("{}_{}_h{}", opts.algo.name(), opts.env, opts.hidden))
        .ok_or_else(|| anyhow::anyhow!("no spec for eval config"))?;
    let tensors = extract_tensors(spec, flat, obs_dim, opts.hidden,
                                  act_dim)?;
    let mut int_engine = match opts.backend {
        EvalBackend::Integer => {
            anyhow::ensure!(opts.quant_on,
                            "integer backend requires a quantized policy");
            Some(IntEngine::new(IntPolicy::from_tensors(&tensors,
                                                        opts.bits)))
        }
        _ => None,
    };
    let hyper = fwd_hyper(rt, opts.bits, opts.quant_on);

    let mut returns = Vec::with_capacity(opts.episodes);
    let mut action = vec![0.0f32; act_dim];
    for _ in 0..opts.episodes {
        let mut obs = env.reset(&mut rng);
        let mut ep = 0.0f64;
        loop {
            let mut x = obs.clone();
            norm.normalize(&mut x);
            if opts.noise_std > 0.0 {
                for v in x.iter_mut() {
                    *v += (rng.normal() * opts.noise_std) as f32;
                }
            }
            match opts.backend {
                EvalBackend::Pjrt => {
                    let out = exe_fwd.as_ref().unwrap().run_f32(&[
                        flat, &x, &hyper,
                    ])?;
                    action.copy_from_slice(&out[0]);
                }
                EvalBackend::FakeQuant => {
                    if opts.quant_on {
                        let a = fakequant::policy_forward(&tensors, &x, 1,
                                                          opts.bits);
                        action.copy_from_slice(&a);
                    } else {
                        fp32_forward(&tensors, &x, &mut action);
                    }
                }
                EvalBackend::Integer => {
                    int_engine.as_mut().unwrap().infer(&x, &mut action);
                }
            }
            let out = env.step(&action);
            ep += out.reward;
            obs = out.obs;
            if out.terminated || out.truncated {
                break;
            }
        }
        returns.push(ep);
    }
    Ok(returns)
}

/// Plain FP32 forward (quant gate off) for the FakeQuant backend.
fn fp32_forward(p: &fakequant::PolicyTensors, x: &[f32], out: &mut [f32]) {
    let matvec = |w: &[f32], b: &[f32], x: &[f32], dout: usize,
                  relu: bool| -> Vec<f32> {
        let din = x.len();
        (0..dout)
            .map(|j| {
                let mut acc = b[j];
                for k in 0..din {
                    acc += w[j * din + k] * x[k];
                }
                if relu { acc.max(0.0) } else { acc }
            })
            .collect()
    };
    let h1 = matvec(p.fc1_w, p.fc1_b, x, p.hidden, true);
    let h2 = matvec(p.fc2_w, p.fc2_b, &h1, p.hidden, true);
    let pre = matvec(p.mean_w, p.mean_b, &h2, p.act_dim, false);
    for (o, v) in out.iter_mut().zip(pre) {
        *o = v.tanh();
    }
}
