//! Deterministic policy evaluation: rollouts with input-noise injection
//! (Fig. 3), driven through the unified [`PolicyBackend`] trait.
//!
//! The interchangeable execution paths — whose agreement is itself a
//! validation of the deployment chain — are resolved *once* into a
//! `Box<dyn PolicyBackend>` before the rollout loop:
//!
//! * `pjrt`      — the AOT `*_fwd_*` artifact (L2 graph incl. the Pallas
//!                 kernel path), wrapped in [`PjrtBackend`],
//! * `fakequant` — the pure-rust fake-quant mirror
//!                 ([`crate::policy::FakeQuantBackend`]),
//! * `fp32`      — the plain FP32 reference
//!                 ([`crate::policy::Fp32Backend`]),
//! * `int`       — the integer-only engine (`intinfer`), i.e. exactly
//!                 what the FPGA executes.

use anyhow::Result;

use super::{fwd_hyper, policy::extract_tensors, Algo};
use crate::envs;
use crate::intinfer::IntEngine;
use crate::policy::{FakeQuantBackend, Fp32Backend, PolicyBackend,
                    PolicyDescriptor};
use crate::quant::export::IntPolicy;
use crate::quant::fakequant::PolicyTensors;
use crate::quant::BitCfg;
use crate::runtime::{Exe, Runtime};
use crate::util::rng::Rng;
use crate::util::stats::{self, ObsNormalizer};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalBackend {
    Pjrt,
    FakeQuant,
    Fp32,
    Integer,
}

impl EvalBackend {
    pub fn parse(s: &str) -> Result<EvalBackend> {
        Ok(match s {
            "pjrt" => EvalBackend::Pjrt,
            "fakequant" => EvalBackend::FakeQuant,
            "fp32" => EvalBackend::Fp32,
            "integer" | "int" => EvalBackend::Integer,
            _ => anyhow::bail!(
                "unknown backend `{s}` (pjrt|fakequant|fp32|int)"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct EvalOpts {
    pub algo: Algo,
    pub env: String,
    pub hidden: usize,
    pub bits: BitCfg,
    pub quant_on: bool,
    pub episodes: usize,
    /// i.i.d. Gaussian noise added to the *normalized* observation
    /// (paper §3.3): ŝ = norm(s) + ε, ε ~ N(0, σ²)
    pub noise_std: f64,
    pub seed: u64,
    pub backend: EvalBackend,
}

/// Resolve the requested execution path into a trait object over the
/// checkpoint's tensors. `flat` must outlive the backend (the PJRT path
/// borrows it as a graph input).
pub fn make_backend<'a>(rt: &Runtime, opts: &EvalOpts, flat: &'a [f32],
                        tensors: &PolicyTensors) -> Result<Box<dyn PolicyBackend + 'a>> {
    Ok(match opts.backend {
        EvalBackend::Pjrt => {
            let exe = rt.exe_for(opts.algo.name(), "fwd", &opts.env,
                                 opts.hidden, Some(1))?;
            let hyper = fwd_hyper(rt, opts.bits, opts.quant_on);
            Box::new(PjrtBackend {
                exe,
                flat,
                hyper,
                obs_dim: tensors.obs_dim,
                act_dim: tensors.act_dim,
                hidden: tensors.hidden,
            })
        }
        // the fake-quant mirror with the quant gate off *is* FP32
        EvalBackend::FakeQuant if opts.quant_on => {
            Box::new(FakeQuantBackend::new(tensors, opts.bits))
        }
        EvalBackend::FakeQuant | EvalBackend::Fp32 => {
            Box::new(Fp32Backend::new(tensors))
        }
        EvalBackend::Integer => {
            anyhow::ensure!(opts.quant_on,
                            "integer backend requires a quantized policy");
            Box::new(IntEngine::new(IntPolicy::from_tensors(tensors,
                                                            opts.bits)))
        }
    })
}

/// Roll out the deterministic policy; returns (mean, std) of episode
/// returns.
pub fn evaluate(rt: &Runtime, opts: &EvalOpts, flat: &[f32],
                norm: &ObsNormalizer) -> Result<(f64, f64)> {
    let returns = evaluate_returns(rt, opts, flat, norm)?;
    Ok((stats::mean(&returns), stats::std(&returns)))
}

/// Full per-episode returns (for robustness bands and selection rules).
pub fn evaluate_returns(rt: &Runtime, opts: &EvalOpts, flat: &[f32],
                        norm: &ObsNormalizer) -> Result<Vec<f64>> {
    let mut env = envs::make(&opts.env)?;
    let (obs_dim, act_dim) = (env.obs_dim(), env.act_dim());
    let mut rng = Rng::new(opts.seed);

    let spec = rt
        .manifest
        .specs
        .get(&format!("{}_{}_h{}", opts.algo.name(), opts.env, opts.hidden))
        .ok_or_else(|| anyhow::anyhow!("no spec for eval config"))?;
    let tensors = extract_tensors(spec, flat, obs_dim, opts.hidden,
                                  act_dim)?;
    let mut backend = make_backend(rt, opts, flat, &tensors)?;

    let mut returns = Vec::with_capacity(opts.episodes);
    let mut action = vec![0.0f32; act_dim];
    for _ in 0..opts.episodes {
        let mut obs = env.reset(&mut rng);
        let mut ep = 0.0f64;
        loop {
            let mut x = obs.clone();
            norm.normalize(&mut x);
            if opts.noise_std > 0.0 {
                for v in x.iter_mut() {
                    *v += (rng.normal() * opts.noise_std) as f32;
                }
            }
            backend.infer(&x, &mut action)?;
            let out = env.step(&action);
            ep += out.reward;
            obs = out.obs;
            if out.terminated || out.truncated {
                break;
            }
        }
        returns.push(ep);
    }
    Ok(returns)
}

/// The AOT-compiled forward graph behind the unified trait: runs the
/// batch-1 `*_fwd_*` executable row by row.
pub struct PjrtBackend<'a> {
    exe: std::sync::Arc<Exe>,
    flat: &'a [f32],
    hyper: Vec<f32>,
    obs_dim: usize,
    act_dim: usize,
    hidden: usize,
}

impl PolicyBackend for PjrtBackend<'_> {
    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.act_dim
    }

    fn infer_batch(&mut self, obs: &[f32], actions_out: &mut [f32])
                   -> Result<()> {
        crate::policy::check_block(obs, actions_out, self.obs_dim,
                                   self.act_dim)?;
        for (x, out) in obs
            .chunks_exact(self.obs_dim)
            .zip(actions_out.chunks_exact_mut(self.act_dim))
        {
            let res = self.exe.run_f32(&[self.flat, x, &self.hyper])?;
            anyhow::ensure!(res[0].len() == self.act_dim,
                            "fwd graph returned {} values, expected {}",
                            res[0].len(), self.act_dim);
            out.copy_from_slice(&res[0]);
        }
        Ok(())
    }

    fn macs(&self) -> u64 {
        crate::policy::mlp_macs(self.obs_dim, self.hidden, self.act_dim)
    }

    fn descriptor(&self) -> PolicyDescriptor {
        PolicyDescriptor {
            id: self.exe.meta.name.clone(),
            kind: "pjrt",
            obs_dim: self.obs_dim,
            act_dim: self.act_dim,
            hidden: self.hidden,
            bits: None,
        }
    }
}
