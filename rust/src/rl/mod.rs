//! RL training orchestration: the rust event loop driving the AOT train
//! graphs (SAC / DDPG) against the rust environments, CleanRL-faithfully.
//!
//! The rust side owns: environment stepping, running input normalization,
//! the replay buffer, exploration noise, the hyper vector, evaluation
//! rollouts, and checkpointing. The gradient math is entirely inside the
//! AOT HLO executables.

pub mod eval;
pub mod policy;

use anyhow::Result;

use crate::envs;
use crate::experiment::{Trial, TrialResult};
use crate::quant::BitCfg;
use crate::replay::Replay;
use crate::runtime::Runtime;
use crate::util::rng::Rng;
use crate::util::stats::ObsNormalizer;

pub use eval::{evaluate, evaluate_returns, evaluate_returns_pooled,
               EvalBackend, EvalOpts, DEFAULT_POOL};
pub use policy::{extract_tensors, init_flat};

/// Which paper algorithm (both from CleanRL).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Sac,
    Ddpg,
}

impl Algo {
    pub fn name(self) -> &'static str {
        match self {
            Algo::Sac => "sac",
            Algo::Ddpg => "ddpg",
        }
    }

    pub fn parse(s: &str) -> Result<Algo> {
        match s {
            "sac" => Ok(Algo::Sac),
            "ddpg" => Ok(Algo::Ddpg),
            _ => anyhow::bail!("unknown algo `{s}` (sac|ddpg)"),
        }
    }
}

/// Training configuration (defaults = paper Appendix A / CleanRL).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub algo: Algo,
    pub env: String,
    pub hidden: usize,
    pub bits: BitCfg,
    /// false = FP32 baseline (the QDQ gate in the graphs bypasses exactly)
    pub quant_on: bool,
    /// running per-dimension input normalization (paper Appendix C)
    pub normalize: bool,
    pub total_steps: usize,
    pub learning_starts: usize,
    pub seed: u64,
    pub lr_policy: f64,
    pub lr_q: f64,
    pub gamma: f64,
    pub tau: f64,
    pub policy_freq: usize,
    pub scale_warmup: usize,
    /// DDPG exploration noise std (CleanRL: 0.1)
    pub exploration_noise: f64,
    pub replay_capacity: usize,
    /// evaluation cadence; 0 disables intermediate evals
    pub eval_every: usize,
    pub eval_episodes: usize,
    /// print progress lines
    pub verbose: bool,
}

impl TrainConfig {
    pub fn new(algo: Algo, env: &str) -> TrainConfig {
        TrainConfig {
            algo,
            env: env.to_string(),
            hidden: 256,
            bits: BitCfg::new(8, 8, 8),
            quant_on: true,
            normalize: true,
            total_steps: 25_000,
            learning_starts: 5_000,
            seed: 1,
            lr_policy: 3e-4,
            lr_q: 1e-3,
            gamma: 0.99,
            tau: 0.005,
            policy_freq: 2,
            scale_warmup: 300,
            exploration_noise: 0.1,
            replay_capacity: 1_000_000,
            eval_every: 0,
            eval_episodes: 10,
            verbose: false,
        }
    }
}

/// A point on the training curve (Fig. 2).
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub step: usize,
    pub mean_return: f64,
    pub std_return: f64,
}

/// Everything a finished run hands back to the coordinator.
pub struct TrainResult {
    pub cfg: TrainConfig,
    pub flat: Vec<f32>,
    pub normalizer: ObsNormalizer,
    pub curve: Vec<CurvePoint>,
    /// returns of the episodes finished *during* training (exploration policy)
    pub train_episode_returns: Vec<f64>,
    pub last_metrics: Vec<f32>,
    pub steps_per_sec: f64,
}

/// Build the hyper vector for a train step.
fn hyper_vec(rt: &Runtime, cfg: &TrainConfig, step: usize, do_policy: bool,
             target_entropy: f64) -> Vec<f32> {
    let m = &rt.manifest;
    let mut h = vec![0.0f32; m.hyper_len];
    h[m.hyper_idx("step")] = step as f32;
    h[m.hyper_idx("lr_policy")] = cfg.lr_policy as f32;
    h[m.hyper_idx("lr_q")] = cfg.lr_q as f32;
    h[m.hyper_idx("lr_alpha")] = cfg.lr_q as f32; // CleanRL: alpha uses q_lr
    h[m.hyper_idx("gamma")] = cfg.gamma as f32;
    h[m.hyper_idx("tau")] = cfg.tau as f32;
    h[m.hyper_idx("do_policy")] = if do_policy { 1.0 } else { 0.0 };
    h[m.hyper_idx("b_in")] = cfg.bits.b_in as f32;
    h[m.hyper_idx("b_core")] = cfg.bits.b_core as f32;
    h[m.hyper_idx("b_out")] = cfg.bits.b_out as f32;
    h[m.hyper_idx("target_entropy")] = target_entropy as f32;
    h[m.hyper_idx("warmup")] = cfg.scale_warmup as f32;
    h[m.hyper_idx("ema_decay")] = 0.9;
    h[m.hyper_idx("quant_on")] = if cfg.quant_on { 1.0 } else { 0.0 };
    h
}

/// Hyper vector for forward/act artifacts (only bits + gate matter).
pub fn fwd_hyper(rt: &Runtime, bits: BitCfg, quant_on: bool) -> Vec<f32> {
    let m = &rt.manifest;
    let mut h = vec![0.0f32; m.hyper_len];
    h[m.hyper_idx("b_in")] = bits.b_in as f32;
    h[m.hyper_idx("b_core")] = bits.b_core as f32;
    h[m.hyper_idx("b_out")] = bits.b_out as f32;
    h[m.hyper_idx("quant_on")] = if quant_on { 1.0 } else { 0.0 };
    h
}

/// Train one policy. Blocking; one OS thread per concurrent run.
pub fn train(rt: &Runtime, cfg: &TrainConfig) -> Result<TrainResult> {
    let t_start = std::time::Instant::now();
    let mut env = envs::make(&cfg.env)?;
    let (obs_dim, act_dim) = (env.obs_dim(), env.act_dim());
    {
        let dims = rt.manifest.envs.get(&cfg.env).ok_or_else(|| {
            anyhow::anyhow!("env `{}` not in manifest", cfg.env)
        })?;
        anyhow::ensure!(dims.obs_dim == obs_dim && dims.act_dim == act_dim,
                        "manifest/env dims mismatch for {}", cfg.env);
    }

    let algo = cfg.algo.name();
    let exe_train = rt.exe_for(algo, "train", &cfg.env, cfg.hidden, None)?;
    let exe_act = match cfg.algo {
        Algo::Sac => Some(rt.exe_for("sac", "act", &cfg.env, cfg.hidden,
                                     None)?),
        Algo::Ddpg => None,
    };
    let exe_fwd = rt.exe_for(algo, "fwd", &cfg.env, cfg.hidden, Some(1))?;

    let spec = &rt.manifest.specs[&exe_train.meta.spec_key];
    let n = spec.n_params;
    let batch = rt.manifest.train_batch;

    let mut rng = Rng::new(cfg.seed);
    let mut env_rng = rng.fork(11);
    let mut init_rng = rng.fork(22);
    let mut eval_seed = cfg.seed ^ 0x5eed;

    let mut flat = init_flat(spec, &mut init_rng);
    let mut m_vec = vec![0.0f32; n];
    let mut v_vec = vec![0.0f32; n];

    let mut norm = ObsNormalizer::new(obs_dim, cfg.normalize);
    let mut replay = Replay::new(
        cfg.replay_capacity.min(cfg.total_steps.max(1)), obs_dim, act_dim);

    // staging buffers (allocation-free loop)
    let mut b_obs = vec![0.0f32; batch * obs_dim];
    let mut b_act = vec![0.0f32; batch * act_dim];
    let mut b_rew = vec![0.0f32; batch];
    let mut b_nobs = vec![0.0f32; batch * obs_dim];
    let mut b_done = vec![0.0f32; batch];
    let mut eps1 = vec![0.0f32; batch * act_dim];
    let mut eps2 = vec![0.0f32; batch * act_dim];
    let mut act_eps = vec![0.0f32; act_dim];

    let target_entropy = -(act_dim as f64);

    let raw_obs = env.reset(&mut env_rng);
    norm.observe(&raw_obs);
    let mut obs_n = raw_obs;
    norm.normalize(&mut obs_n);

    let mut curve: Vec<CurvePoint> = Vec::new();
    let mut train_episode_returns: Vec<f64> = Vec::new();
    let mut ep_return = 0.0f64;
    let mut last_metrics = vec![0.0f32; rt.manifest.metric_len];
    let mut update_count: usize = 0;

    for t in 0..cfg.total_steps {
        // ---- act ----------------------------------------------------------
        let action: Vec<f32> = if t < cfg.learning_starts {
            (0..act_dim)
                .map(|_| rng.uniform_in(-1.0, 1.0) as f32)
                .collect()
        } else {
            match cfg.algo {
                Algo::Sac => {
                    rng.fill_normal(&mut act_eps);
                    let h = fwd_hyper(rt, cfg.bits, cfg.quant_on);
                    let out = exe_act.as_ref().unwrap().run_f32(&[
                        &flat, &obs_n, &act_eps, &h,
                    ])?;
                    out.into_iter().next().unwrap()
                }
                Algo::Ddpg => {
                    let h = fwd_hyper(rt, cfg.bits, cfg.quant_on);
                    let out = exe_fwd.run_f32(&[&flat, &obs_n, &h])?;
                    out[0]
                        .iter()
                        .map(|&a| {
                            (a + (rng.normal() * cfg.exploration_noise) as f32)
                                .clamp(-1.0, 1.0)
                        })
                        .collect()
                }
            }
        };

        // ---- env step -------------------------------------------------------
        let out = env.step(&action);
        ep_return += out.reward;
        let mut next_n = out.obs.clone();
        norm.observe(&out.obs);
        norm.normalize(&mut next_n);
        replay.push(&obs_n, &action, out.reward as f32, &next_n,
                    out.terminated);

        if out.terminated || out.truncated {
            train_episode_returns.push(ep_return);
            ep_return = 0.0;
            let raw = env.reset(&mut env_rng);
            norm.observe(&raw);
            obs_n = raw;
            norm.normalize(&mut obs_n);
        } else {
            obs_n = next_n;
        }

        // ---- learn ----------------------------------------------------------
        if t >= cfg.learning_starts {
            update_count += 1;
            replay.sample_into(&mut rng, batch, &mut b_obs, &mut b_act,
                               &mut b_rew, &mut b_nobs, &mut b_done);
            let do_policy = update_count % cfg.policy_freq == 0;
            let h = hyper_vec(rt, cfg, update_count, do_policy,
                              target_entropy);
            let outs = match cfg.algo {
                Algo::Sac => {
                    rng.fill_normal(&mut eps1);
                    rng.fill_normal(&mut eps2);
                    exe_train.run_f32(&[
                        &flat, &m_vec, &v_vec, &b_obs, &b_act, &b_rew,
                        &b_nobs, &b_done, &eps1, &eps2, &h,
                    ])?
                }
                Algo::Ddpg => exe_train.run_f32(&[
                    &flat, &m_vec, &v_vec, &b_obs, &b_act, &b_rew, &b_nobs,
                    &b_done, &h,
                ])?,
            };
            let mut it = outs.into_iter();
            flat = it.next().unwrap();
            m_vec = it.next().unwrap();
            v_vec = it.next().unwrap();
            last_metrics = it.next().unwrap();
            anyhow::ensure!(
                last_metrics.iter().all(|x| x.is_finite()),
                "non-finite training metrics at step {t}: {last_metrics:?}"
            );
        }

        // ---- evaluate ---------------------------------------------------------
        if cfg.eval_every > 0
            && t >= cfg.learning_starts
            && (t + 1) % cfg.eval_every == 0
        {
            eval_seed = eval_seed.wrapping_add(1);
            let (mean, std) = evaluate(rt, &EvalOpts {
                algo: cfg.algo,
                scenario: envs::Scenario::bare(&cfg.env),
                hidden: cfg.hidden,
                bits: cfg.bits,
                quant_on: cfg.quant_on,
                episodes: cfg.eval_episodes,
                seed: eval_seed,
                backend: EvalBackend::Pjrt,
                lbits: None,
            }, &flat, &norm)?;
            if cfg.verbose {
                println!(
                    "  [{:>6}/{}] eval {:8.1} ± {:6.1}   qf1 {:.3}  \
                     alpha {:.3}  s_in {:.3}",
                    t + 1, cfg.total_steps, mean, std,
                    last_metrics[rt.manifest.metric_idx("qf1_loss")],
                    last_metrics[rt.manifest.metric_idx("alpha")],
                    last_metrics[rt.manifest.metric_idx("s_in")]);
            }
            curve.push(CurvePoint { step: t + 1, mean_return: mean,
                                    std_return: std });
        }
    }

    let steps_per_sec =
        cfg.total_steps as f64 / t_start.elapsed().as_secs_f64().max(1e-9);
    norm.freeze();
    Ok(TrainResult {
        cfg: cfg.clone(),
        flat,
        normalizer: norm,
        curve,
        train_episode_returns,
        last_metrics,
        steps_per_sec,
    })
}

/// A finished trial: the deterministic result record plus the full
/// training output (weights + normalizer) for callers that export or
/// checkpoint.
pub struct TrialRun {
    pub result: TrialResult,
    pub train: TrainResult,
}

/// Trial-granular entry point: train one [`Trial`] and evaluate it with
/// the trial-derived eval seed. Every source of randomness comes from
/// the trial's own fields, so the outcome is independent of which
/// executor worker (or process) runs it.
pub fn run_trial(rt: &Runtime, trial: &Trial) -> Result<TrialRun> {
    // fail fast: an unparsable scenario suffix must error before the
    // training budget is spent, not at the post-training evaluate
    let scenario = trial.scenario()?;
    let mut cfg = TrainConfig::new(trial.algo, &trial.env);
    cfg.hidden = trial.hidden;
    cfg.bits = trial.bits;
    cfg.quant_on = trial.quant_on;
    cfg.normalize = trial.normalize;
    cfg.total_steps = trial.steps;
    cfg.learning_starts = trial.learning_starts;
    cfg.seed = trial.seed;
    let train = self::train(rt, &cfg)?;
    let (eval_mean, eval_std) = evaluate(rt, &EvalOpts {
        algo: trial.algo,
        // evaluation runs under the trial's scenario (bare when unset);
        // training itself always sees the clean environment
        scenario,
        hidden: trial.hidden,
        bits: trial.bits,
        quant_on: trial.quant_on,
        episodes: trial.eval_episodes,
        seed: trial.eval_seed(),
        // a per-layer allocation only exists on the integer engine:
        // mixed trials train at the envelope triple (above) and are
        // scored on exactly what the FPGA would execute
        backend: if trial.lbits.is_some() { EvalBackend::Integer }
                 else { EvalBackend::Pjrt },
        lbits: trial.lbits.clone(),
    }, &train.flat, &train.normalizer)?;
    Ok(TrialRun {
        result: TrialResult {
            trial_id: trial.id(),
            eval_mean,
            eval_std,
            ckpt: None,
        },
        train,
    })
}
