//! xoshiro256++ PRNG with Gaussian sampling (Box–Muller).
//!
//! Replaces the `rand` crate (unavailable offline). The generator feeds the
//! environments, exploration noise, replay sampling, and the Gaussian noise
//! tensors the RNG-free AOT graphs take as inputs.

/// xoshiro256++ by Blackman & Vigna — fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

/// splitmix64: seeds the xoshiro state from a single u64.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (e.g. per training seed / per thread).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's unbiased multiply-shift rejection
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill a slice with iid standard normals (f32).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Fill with uniforms in [lo, hi).
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform_in(lo as f64, hi as f64) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(5);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
