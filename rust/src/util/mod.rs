//! Dependency-free substrates: RNG, JSON, statistics, CLI parsing, property
//! testing, and a tiny bench harness.
//!
//! The build environment is offline with only the `xla` crate closure
//! available, so the conventional crates (rand, serde, clap, criterion,
//! proptest) are replaced by these modules (DESIGN.md §Substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod testkit;

/// Round half-to-even, matching XLA's `round_nearest_even` and therefore the
/// L2 graphs bit-for-bit. (`f32::round` rounds half away from zero, which
/// would diverge from the AOT artifacts on exact .5 lattice boundaries.)
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    x.round_ties_even()
}

/// Format a byte count human-readably (used by reports).
pub fn human_bytes(n: u64) -> String {
    if n >= 1 << 20 {
        format!("{:.1} MiB", n as f64 / (1u64 << 20) as f64)
    } else if n >= 1 << 10 {
        format!("{:.1} KiB", n as f64 / 1024.0)
    } else {
        format!("{n} B")
    }
}

/// Format seconds with an engineering suffix (µs/ms/s) for latency reports.
pub fn human_time(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ties_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(100), "100 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_time(2e-6), "2.0 µs");
        assert_eq!(human_time(0.25), "250.0 ms");
    }
}
