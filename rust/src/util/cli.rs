//! Tiny CLI argument parser (replaces `clap`, unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters with defaults keep call sites terse.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{key}={s}")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{key}={s}")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{key}={s}")),
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.str_opt(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(s) => bail!("--{key}={s}: expected a boolean"),
        }
    }

    /// Comma-separated list, e.g. `--bits 2,3,4`.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.str_opt(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(s) => s.split(',').map(|t| t.trim().to_string()).collect(),
        }
    }

    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.str_opt(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|t| t.trim().parse().with_context(|| format!("--{key}={s}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn kinds() {
        let a = parse(&["train", "--env", "hopper", "--steps=5000",
                        "--verbose", "--lr", "3e-4"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.str("env", "x"), "hopper");
        assert_eq!(a.usize("steps", 0).unwrap(), 5000);
        assert!(a.bool("verbose", false).unwrap());
        assert!((a.f64("lr", 0.0).unwrap() - 3e-4).abs() < 1e-12);
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn lists() {
        let a = parse(&["--bits", "2,3,4", "--envs=hopper, ant"]);
        assert_eq!(a.usize_list("bits", &[]).unwrap(), vec![2, 3, 4]);
        assert_eq!(a.list("envs", &[]), vec!["hopper", "ant"]);
        assert_eq!(a.usize_list("other", &[8]).unwrap(), vec![8]);
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.usize("steps", 0).is_err());
    }
}
