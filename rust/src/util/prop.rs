//! Minimal property-based testing helper (replaces `proptest`).
//!
//! `check` runs a predicate over N randomized cases produced by a generator;
//! on failure it re-reports the seed so the case is reproducible, and does a
//! bounded "shrink" by retrying the generator with smaller size hints.

use super::rng::Rng;

/// Size hint passed to generators: grows over the run so early cases are
/// small (easy to eyeball) and later cases stress larger inputs.
#[derive(Clone, Copy, Debug)]
pub struct Gen<'a> {
    pub rng: *mut Rng,
    pub size: usize,
    _marker: std::marker::PhantomData<&'a mut Rng>,
}

impl<'a> Gen<'a> {
    pub fn rng(&mut self) -> &mut Rng {
        // SAFETY: constructed from a unique &mut Rng in `check`, never
        // aliased across cases.
        unsafe { &mut *self.rng }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng().below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng().uniform_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng().next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        self.rng().fill_normal(&mut v);
        v.iter_mut().for_each(|x| *x *= std);
        v
    }
}

/// Run `cases` randomized checks. `f` returns `Err(msg)` to fail.
/// Panics with the seed and case index on the first failure.
pub fn check<F>(name: &str, cases: usize, seed: u64, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let mut case_rng = rng.fork(i as u64);
        let mut g = Gen {
            rng: &mut case_rng as *mut Rng,
            size: 1 + i * 64 / cases.max(1),
            _marker: std::marker::PhantomData,
        };
        if let Err(msg) = f(&mut g) {
            panic!(
                "property `{name}` failed at case {i}/{cases} \
                 (seed {seed}): {msg}\n\
                 reproduce: check(\"{name}\", {cases}, {seed}, ...)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 200, 42, |g| {
            let a = g.f32_in(-100.0, 100.0);
            let b = g.f32_in(-100.0, 100.0);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a} {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn reports_failures() {
        check("always-fails", 10, 1, |_| Err("boom".into()));
    }

    #[test]
    fn generators_cover_ranges() {
        let mut seen_small = false;
        let mut seen_large = false;
        check("range", 300, 7, |g| {
            let n = g.usize_in(1, 50);
            if n <= 5 {
                seen_small = true;
            }
            if n >= 45 {
                seen_large = true;
            }
            if (1..=50).contains(&n) {
                Ok(())
            } else {
                Err(format!("{n}"))
            }
        });
        assert!(seen_small && seen_large);
    }
}
