//! Statistics helpers: running mean/std (Welford), per-dimension running
//! normalization (the paper's input normalization, frozen at evaluation),
//! percentiles, and summary formatting for the experiment tables.

/// Welford running mean/variance over scalars.
#[derive(Clone, Debug, Default)]
pub struct Running {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Running {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Per-dimension running normalization of observations (paper Appendix C):
/// maintains mean/var per input dimension during training; `frozen` stops
/// updates at evaluation/deployment time.
#[derive(Clone, Debug)]
pub struct ObsNormalizer {
    pub enabled: bool,
    pub frozen: bool,
    n: f64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl ObsNormalizer {
    pub fn new(dim: usize, enabled: bool) -> Self {
        ObsNormalizer {
            enabled,
            frozen: false,
            n: 0.0,
            mean: vec![0.0; dim],
            m2: vec![0.0; dim],
        }
    }

    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Update statistics with one raw observation (no-op when frozen or
    /// disabled).
    pub fn observe(&mut self, obs: &[f32]) {
        if !self.enabled || self.frozen {
            return;
        }
        debug_assert_eq!(obs.len(), self.mean.len());
        self.n += 1.0;
        for (i, &x) in obs.iter().enumerate() {
            let d = x as f64 - self.mean[i];
            self.mean[i] += d / self.n;
            self.m2[i] += d * (x as f64 - self.mean[i]);
        }
    }

    /// Normalize in place: (x - mean) / sqrt(var + 1e-8), clipped to ±10
    /// (standard running-normalization practice; keeps quantizer scales sane).
    pub fn normalize(&self, obs: &mut [f32]) {
        if !self.enabled {
            return;
        }
        for (i, x) in obs.iter_mut().enumerate() {
            let var = if self.n >= 2.0 {
                self.m2[i] / (self.n - 1.0)
            } else {
                1.0
            };
            let z = (*x as f64 - self.mean[i]) / (var + 1e-8).sqrt();
            *x = z.clamp(-10.0, 10.0) as f32;
        }
    }

    pub fn freeze(&mut self) {
        self.frozen = true;
    }

    /// Serialize to (mean, var) pairs for checkpointing/export.
    pub fn state(&self) -> (Vec<f64>, Vec<f64>) {
        let var: Vec<f64> = self
            .m2
            .iter()
            .map(|&m2| if self.n >= 2.0 { m2 / (self.n - 1.0) } else { 1.0 })
            .collect();
        (self.mean.clone(), var)
    }

    pub fn load_state(&mut self, mean: Vec<f64>, var: Vec<f64>, n: f64) {
        self.m2 = var.iter().map(|v| v * (n - 1.0).max(1.0)).collect();
        self.mean = mean;
        self.n = n;
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64)
        .sqrt()
}

/// Linear-interpolation percentile (q in [0,1]) of an unsorted slice.
///
/// NaN-tolerant: samples are ordered with `f64::total_cmp`, so stray NaNs
/// can never panic the sort (the old `partial_cmp().unwrap()` did). Note
/// total order places positive NaN above +inf but *negative* NaN below
/// -inf, so a quantile landing on a NaN sample returns NaN — the guarantee
/// here is no-panic, not NaN-free output.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, q)
}

/// [`percentile`] over an already `total_cmp`-sorted slice — lets callers
/// that need several quantiles sort once instead of once per quantile.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// "5.1k ± 0.9k"-style formatting used by the paper's tables.
pub fn fmt_pm(mean: f64, std: f64) -> String {
    fn k(x: f64) -> String {
        if x.abs() >= 1000.0 {
            format!("{:.1}k", x / 1000.0)
        } else {
            format!("{x:.0}")
        }
    }
    format!("{} ± {}", k(mean), k(std))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::default();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 4.0).abs() < 1e-12);
        assert!((r.std() - std(&xs)).abs() < 1e-12);
    }

    #[test]
    fn normalizer_whitens() {
        let mut n = ObsNormalizer::new(2, true);
        let mut rng = crate::util::rng::Rng::new(0);
        for _ in 0..5000 {
            let o = [5.0 + 2.0 * rng.normal() as f32,
                     -3.0 + 0.5 * rng.normal() as f32];
            n.observe(&o);
        }
        let mut probe = [5.0f32, -3.0];
        n.normalize(&mut probe);
        assert!(probe[0].abs() < 0.1, "{probe:?}");
        assert!(probe[1].abs() < 0.1, "{probe:?}");
        let mut probe2 = [7.0f32, -2.5];
        n.normalize(&mut probe2);
        assert!((probe2[0] - 1.0).abs() < 0.1, "{probe2:?}");
        assert!((probe2[1] - 1.0).abs() < 0.1, "{probe2:?}");
    }

    #[test]
    fn normalizer_freeze_stops_updates() {
        let mut n = ObsNormalizer::new(1, true);
        for i in 0..100 {
            n.observe(&[i as f32]);
        }
        n.freeze();
        let (m0, _) = n.state();
        n.observe(&[1e6]);
        let (m1, _) = n.state();
        assert_eq!(m0, m1);
    }

    #[test]
    fn disabled_normalizer_is_identity() {
        let mut n = ObsNormalizer::new(1, false);
        n.observe(&[100.0]);
        let mut x = [42.0f32];
        n.normalize(&mut x);
        assert_eq!(x[0], 42.0);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 3.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
    }

    #[test]
    fn percentile_small_n_edge_cases() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[], 0.99), 0.0);
        assert_eq!(percentile(&[5.0], 0.5), 5.0);
        assert_eq!(percentile(&[5.0], 0.99), 5.0);
        // n = 2: linear interpolation between the two samples
        assert_eq!(percentile(&[1.0, 3.0], 0.5), 2.0);
        assert!((percentile(&[1.0, 3.0], 0.99) - 2.98).abs() < 1e-12);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // a NaN sample must not panic the sort (total_cmp orders it last)
        let xs = [1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
        // the top quantile lands on the NaN itself — defined, not a panic
        assert!(percentile(&xs, 1.0).is_nan());
    }

    #[test]
    fn fmt_paper_style() {
        assert_eq!(fmt_pm(5100.0, 930.0), "5.1k ± 930");
        assert_eq!(fmt_pm(12.0, 3.0), "12 ± 3");
    }
}
