//! Deterministic toy policies for tests, benches, and example fallbacks.
//!
//! Several surfaces need a self-contained [`IntPolicy`] with no trained
//! artifacts — the serving integration tests, the throughput bench, the
//! back-compat server test, and `examples/policy_server.rs` when PJRT is
//! unavailable. One builder here keeps them from drifting apart.

use crate::policy::OwnedTensors;
use crate::quant::export::IntPolicy;
use crate::quant::{BitCfg, LayerBits};
use crate::util::rng::Rng;

/// Deterministic random 3-layer FP32 tensors of the given dimensions
/// (same seed + dims → identical tensors). The one toy-policy recipe:
/// [`toy_policy`] quantizes these, and surfaces that need the FP32 side
/// too (e.g. the fig3 surrogate's int-vs-fp32 pair) build from the same
/// tensors instead of re-rolling their own.
pub fn toy_tensors(seed: u64, obs_dim: usize, hidden: usize,
                   act_dim: usize) -> OwnedTensors {
    let mut r = Rng::new(seed);
    let mut mk = |n: usize, s: f32| -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        r.fill_normal(&mut v);
        v.iter_mut().for_each(|x| *x *= s);
        v
    };
    OwnedTensors {
        obs_dim,
        hidden,
        act_dim,
        fc1_w: mk(hidden * obs_dim, 0.5),
        fc1_b: mk(hidden, 0.1),
        fc2_w: mk(hidden * hidden, 0.3),
        fc2_b: mk(hidden, 0.1),
        mean_w: mk(act_dim * hidden, 0.3),
        mean_b: mk(act_dim, 0.1),
        s_in: 2.0,
        s_h1: 1.2,
        s_h2: 1.2,
        s_out: 1.0,
    }
}

/// Build a deterministic random 3-layer integer policy of the given
/// dimensions (same seed + dims + bits → identical policy).
pub fn toy_policy(seed: u64, obs_dim: usize, hidden: usize,
                  act_dim: usize, bits: BitCfg) -> IntPolicy {
    IntPolicy::from_tensors(
        &toy_tensors(seed, obs_dim, hidden, act_dim).views(), bits)
}

/// [`toy_policy`] with a heterogeneous per-layer allocation (same seed +
/// dims + allocation → identical policy). Fails only if the allocation
/// itself is malformed.
pub fn toy_policy_mixed(seed: u64, obs_dim: usize, hidden: usize,
                        act_dim: usize, lb: &LayerBits)
                        -> anyhow::Result<IntPolicy> {
    IntPolicy::from_tensors_mixed(
        &toy_tensors(seed, obs_dim, hidden, act_dim).views(), lb)
}

/// A toy policy with planted all-zero weight rows: the first `dead_h1`
/// rows of fc1 and the first `dead_h2` rows of fc2 are zeroed in FP32
/// (zero rows quantize to zero rows at any bit width; biases are left
/// alone, so the dead rows produce nonzero constants the prune pass
/// must fold into downstream thresholds — the dead-row/column pruning
/// vehicle for tests and the `qir_opt` bench).
pub fn sparse_toy_policy(seed: u64, obs_dim: usize, hidden: usize,
                         act_dim: usize, bits: BitCfg,
                         dead_h1: usize, dead_h2: usize) -> IntPolicy {
    let mut t = toy_tensors(seed, obs_dim, hidden, act_dim);
    for j in 0..dead_h1.min(hidden) {
        t.fc1_w[j * obs_dim..(j + 1) * obs_dim].fill(0.0);
    }
    for j in 0..dead_h2.min(hidden) {
        t.fc2_w[j * hidden..(j + 1) * hidden].fill(0.0);
    }
    IntPolicy::from_tensors(&t.views(), bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::intinfer::IntEngine;

    #[test]
    fn deterministic_across_calls() {
        let a = toy_policy(9, 4, 8, 2, BitCfg::new(4, 3, 8));
        let b = toy_policy(9, 4, 8, 2, BitCfg::new(4, 3, 8));
        let mut ea = IntEngine::new(a);
        let mut eb = IntEngine::new(b);
        let obs = [0.3f32, -1.1, 0.0, 2.0];
        assert_eq!(ea.infer_vec(&obs), eb.infer_vec(&obs));
    }
}
