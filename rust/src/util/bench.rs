//! Micro-bench harness (replaces `criterion`, unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that use
//! [`Bench::run`] for timing loops (warmup + timed iterations, reporting
//! mean / p50 / p99) and the table printers in the bench files for the
//! paper-shaped outputs.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:42} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f` for at least `min_time_s` seconds (after `warmup` calls),
/// one sample per call.
pub fn run<F: FnMut()>(name: &str, warmup: usize, min_time_s: f64,
                       mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples_ns: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_time_s
        || samples_ns.len() < 10
    {
        let t = Instant::now();
        f();
        samples_ns.push(t.elapsed().as_nanos() as f64);
        if samples_ns.len() >= 1_000_000 {
            break;
        }
    }
    samples_ns.sort_by(f64::total_cmp);
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: samples_ns[n / 2],
        p99_ns: samples_ns[(n as f64 * 0.99) as usize % n],
        min_ns: samples_ns[0],
    };
    r.print();
    r
}

/// Simple fixed-width table printer for the paper-shaped bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let r = run("noop-ish", 2, 0.01, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p99_ns);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["env", "reward"]);
        t.row(vec!["hopper".into(), "2.7k ± 0.7k".into()]);
        t.print();
    }
}
