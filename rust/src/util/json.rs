//! Minimal JSON: a recursive-descent parser and an emitter.
//!
//! Replaces `serde_json` (unavailable offline). Parses the artifact
//! `manifest.json`, the golden parity vectors, experiment configs, and
//! serializes result stores. Supports the full JSON grammar; numbers are
//! held as f64 (adequate for f32 payloads and indices).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking up `{key}`)"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f32> (bulk path for golden vectors).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- construction helpers ---------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- emit ----------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s);
        s
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => emit_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.emit(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    v.emit(out);
                }
                out.push('}');
            }
        }
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parse -------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        bail!("trailing data at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}, found `{}`",
                  c as char, self.i, self.b[self.i] as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} found `{}` at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] found `{}` at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // (surrogate pairs unsupported; manifest is ASCII)
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // copy UTF-8 continuation bytes verbatim
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk = std::str::from_utf8(
                            &self.b[start..start + len])?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null},
                      "s": "hi\nthere", "neg": -0.125}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f32_vec().unwrap(),
                   vec![1.0, 2.5, -300.0]);
        assert!(v.get("b").unwrap().get("c").unwrap().as_bool().unwrap());
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "hi\nthere");
        // re-parse what we emit
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""Aµ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aµ");
    }

    #[test]
    fn big_float_array() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.25 - 100.0).collect();
        let j = Json::arr_f32(&xs);
        let back = parse(&j.to_string()).unwrap().as_f32_vec().unwrap();
        assert_eq!(xs, back);
    }
}
