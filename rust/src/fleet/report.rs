//! The machine-readable fleet report (`fleet.json`): per-cohort return
//! distributions joined with the server-side telemetry captured over
//! the monitor protocol *during the same run* — the first artifact in
//! the repo where reward and tail latency degrade together or not at
//! all.

use std::collections::BTreeMap;

use crate::coordinator::serving::ServerStats;
use crate::util::json::Json;
use crate::util::stats;

use super::population::Cohort;
use super::remote::RemoteCounters;

/// One cohort's return distribution.
#[derive(Clone, Debug)]
pub struct CohortReport {
    pub label: String,
    pub policy: Option<String>,
    pub weight: f64,
    pub episodes: usize,
    pub returns: Vec<f64>,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl CohortReport {
    pub fn new(cohort: &Cohort, returns: Vec<f64>) -> CohortReport {
        let mean = stats::mean(&returns);
        let p50 = stats::percentile(&returns, 50.0);
        let p99 = stats::percentile(&returns, 99.0);
        let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
        for &r in &returns {
            min = min.min(r);
            max = max.max(r);
        }
        if returns.is_empty() {
            min = 0.0;
            max = 0.0;
        }
        CohortReport {
            label: cohort.label.clone(),
            policy: cohort.policy.clone(),
            weight: cohort.weight,
            episodes: returns.len(),
            returns,
            mean,
            p50,
            p99,
            min,
            max,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            ("policy", match &self.policy {
                Some(p) => Json::str(p),
                None => Json::str(""),
            }),
            ("weight", Json::num(self.weight)),
            ("episodes", Json::num(self.episodes as f64)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p99", Json::num(self.p99)),
            ("min", Json::num(self.min)),
            ("max", Json::num(self.max)),
            ("returns", Json::Arr(
                self.returns.iter().map(|&r| Json::num(r)).collect())),
        ])
    }
}

/// The merged view of the monitor stream over the run: last complete
/// per-policy state (diffs overlaid on the snapshot), the ordered ops
/// event feed, and the peak aggregate QPS observed across frames.
#[derive(Clone, Debug, Default)]
pub struct MonitorSummary {
    pub frames: u64,
    pub peak_qps: f64,
    /// merged per-policy fields (version, qps, mean_batch, p50/p99/
    /// p999_us, ...), keyed by policy id
    pub policies: BTreeMap<String, BTreeMap<String, Json>>,
    /// last `server` block seen (reloads, reload_failures, ...)
    pub server: Option<Json>,
    pub events: Vec<Json>,
}

impl MonitorSummary {
    /// Overlay one monitor frame (full or diff) onto the merged state.
    /// Malformed frames are skipped — telemetry capture must never
    /// fail the run it observes.
    pub fn merge(&mut self, frame: &Json) {
        self.frames += 1;
        if let Ok(policies) = frame.get("policies").and_then(|p| {
            p.as_obj().map(|m| m.clone())
        }) {
            for (id, fields) in policies {
                let slot = self.policies.entry(id).or_default();
                if let Ok(src) = fields.as_obj() {
                    for (k, v) in src {
                        slot.insert(k.clone(), v.clone());
                    }
                }
            }
        }
        let total_qps: f64 = self
            .policies
            .values()
            .filter_map(|f| f.get("qps"))
            .filter_map(|v| v.as_f64().ok())
            .sum();
        self.peak_qps = self.peak_qps.max(total_qps);
        if let Ok(server) = frame.get("server") {
            self.server = Some(server.clone());
        }
        if let Ok(events) = frame.get("events").and_then(|e| {
            e.as_arr().map(|a| a.to_vec())
        }) {
            self.events.extend(events);
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("frames", Json::num(self.frames as f64)),
            ("peak_qps", Json::num(self.peak_qps)),
            ("policies", Json::Obj(
                self.policies
                    .iter()
                    .map(|(id, f)| (id.clone(), Json::Obj(f.clone())))
                    .collect())),
            ("server", self.server.clone()
                .unwrap_or(Json::Obj(BTreeMap::new()))),
            ("events", Json::Arr(self.events.clone())),
        ])
    }
}

/// Everything one fleet run produced.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub env: String,
    pub spec: String,
    pub episodes: usize,
    pub block: usize,
    pub jobs: usize,
    pub seed: u64,
    pub cohorts: Vec<CohortReport>,
    /// aggregated client-side wire/fault counters
    pub counters: RemoteCounters,
    /// server-side hot reloads injected and confirmed during the run
    pub injected_reloads: u64,
    /// final aggregate server stats (joined after shutdown)
    pub server: ServerStats,
    /// telemetry captured over the monitor protocol during the run
    pub monitor: MonitorSummary,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::num(1.0)),
            ("env", Json::str(&self.env)),
            ("population", Json::str(&self.spec)),
            ("episodes", Json::num(self.episodes as f64)),
            ("block", Json::num(self.block as f64)),
            ("jobs", Json::num(self.jobs as f64)),
            ("seed", Json::str(self.seed.to_string())),
            ("cohorts", Json::Arr(
                self.cohorts.iter().map(|c| c.to_json()).collect())),
            ("client", Json::obj(vec![
                ("requests", Json::num(self.counters.requests as f64)),
                ("forced_drops",
                 Json::num(self.counters.forced_drops as f64)),
                ("recovered", Json::num(self.counters.recovered as f64)),
                ("delayed", Json::num(self.counters.delayed as f64)),
                ("reloads_observed",
                 Json::num(self.counters.reloads_observed as f64)),
                // a FleetReport only exists for a run with no
                // unrecovered client errors (they abort the run)
                ("unrecovered_errors", Json::num(0.0)),
            ])),
            ("injected_reloads", Json::num(self.injected_reloads as f64)),
            ("server", Json::obj(vec![
                ("requests", Json::num(self.server.requests as f64)),
                ("connections",
                 Json::num(self.server.connections as f64)),
                ("batches", Json::num(self.server.batches as f64)),
                ("mean_batch", Json::num(if self.server.batches == 0 {
                    0.0
                } else {
                    self.server.requests as f64
                        / self.server.batches as f64
                })),
                ("io_errors", Json::num(self.server.io_errors as f64)),
                ("busy_replies",
                 Json::num(self.server.busy_replies as f64)),
                ("rejected_conns",
                 Json::num(self.server.rejected_conns as f64)),
                ("reloads", Json::num(self.server.reloads as f64)),
                ("p50_us", Json::num(self.server.p50_us)),
                ("p99_us", Json::num(self.server.p99_us)),
                ("p999_us", Json::num(self.server.p999_us)),
            ])),
            ("monitor", self.monitor.to_json()),
        ])
    }
}
