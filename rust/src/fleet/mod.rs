//! Fleet simulation: a population-scale closed loop between the
//! vectorized episode pool and the live serving subsystem.
//!
//! `qcontrol robustness` measures returns in process; the serving bench
//! measures latency against synthetic frames. This module closes
//! ROADMAP item 3 by measuring **both in the same run**: thousands of
//! concurrent scenario-wrapped episodes (the PR-4 grammar) whose every
//! action comes over the real v2/v3 wire protocol from a live
//! [`serve_registry`] process on loopback, while the PR-7 monitor
//! protocol streams the server's own view of the load.
//!
//! ```text
//!  run_fleet
//!    ├── serve_registry thread        (staged .qpol dir, ops plane,
//!    │                                 ephemeral loopback port)
//!    ├── monitor-capture thread       (MonitorClient; merges the
//!    │                                 diff stream → MonitorSummary)
//!    ├── reload-injection thread      (tmp+rename republish, version
//!    │                                 confirmed over the wire)
//!    └── J worker threads, each:      (cohort, block) queue →
//!          VecEnv(scenario, width=block)
//!            └─ RemoteBackend ──────── v3 framed requests ──→ server
//! ```
//!
//! ## Determinism at any concurrency
//!
//! Each cohort's episodes are split into fixed-size blocks
//! ([`Population::blocks`]); a block is one [`VecEnv::rollout_returns`]
//! call seeded by [`population::block_seed`]. Block structure depends
//! only on `(spec, episodes, block)` — never on `--jobs` — and the
//! `VecEnv` pool invariant plus the serving core's row-wise determinism
//! make each block's returns a pure function of its seed. Workers steal
//! blocks from a shared queue and write results into slots keyed by
//! episode index, so a fleet run is bit-identical at any job count —
//! including runs with injected faults, because a hot-republished
//! artifact carries the same weights, and reconnect-resent
//! observations yield the same actions.
//!
//! Normalization note: the serving core normalizes raw wire
//! observations with each artifact's frozen normalizer, so fleet
//! environments carry **no** client-side `Normalize` layer — scenario
//! perturbations act on raw sensor readings, the deployment-realistic
//! convention (`qcontrol robustness` instead perturbs normalized
//! state; the two harnesses agree only for bare scenarios).

pub mod population;
pub mod remote;
pub mod report;

use std::collections::{BTreeMap, VecDeque};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::ops::{MonitorClient, OpsConfig};
use crate::coordinator::serving::{serve_registry, ClientConfig,
                                  RoutedClient, ServerConfig};
use crate::envs::VecEnv;
use crate::policy::{PolicyArtifact, PolicyRegistry};

pub use population::{block_seed, Cohort, Population};
pub use remote::{FaultSpec, RemoteBackend, RemoteCounters, ServerMirror};
pub use report::{CohortReport, FleetReport, MonitorSummary};

/// Everything one fleet run needs besides the artifacts.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// population spec (see [`population`] grammar)
    pub spec: String,
    /// environment override; `None` = the default artifact's env
    pub env: Option<String>,
    /// total episodes across all cohorts
    pub episodes: usize,
    /// episodes per rollout block — the lockstep width of each
    /// `VecEnv`, and the determinism unit (results are invariant to
    /// `jobs`, *not* to `block`)
    pub block: usize,
    /// worker threads; concurrent in-flight episodes peak at
    /// `jobs * block`
    pub jobs: usize,
    /// fleet seed; all block seeds derive from it by FNV-1a
    pub seed: u64,
    /// policy served to cohorts without an explicit `@policy`;
    /// `None` = the registry's first id in sorted order
    pub default_policy: Option<String>,
    /// client-side fault injection (forced drops, delayed frames)
    pub faults: FaultSpec,
    /// server-side fault injection: hot republishes of the default
    /// policy (tmp+rename, confirmed over the wire) during the run
    pub reloads: u64,
    /// wire client timeouts/backoff
    pub client: ClientConfig,
    /// server batch limit
    pub max_batch: usize,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            spec: "70%=nominal 20%=sensor-noise 10%=sim2real".to_string(),
            env: None,
            episodes: 2000,
            block: 250,
            jobs: 4,
            seed: 42,
            default_policy: None,
            faults: FaultSpec::default(),
            reloads: 0,
            client: ClientConfig::default(),
            max_batch: 32,
        }
    }
}

impl FleetConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.episodes > 0, "fleet episodes must be >= 1");
        anyhow::ensure!(self.block > 0, "fleet block must be >= 1");
        anyhow::ensure!(self.jobs > 0, "fleet jobs must be >= 1");
        anyhow::ensure!(self.max_batch > 0, "fleet max_batch must be >= 1");
        self.client.validate()
    }
}

/// Distinguishes concurrent fleet runs in one process (tests run in
/// parallel threads; the pid alone would collide their stage dirs).
static STAGE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Run one fleet simulation: stage the artifacts, self-host a
/// [`serve_registry`] on an ephemeral loopback port with the ops plane
/// attached, drive the population through it, and join client-side
/// return distributions with the server's telemetry. Any unrecovered
/// client error aborts the run with a descriptive error — a returned
/// [`FleetReport`] certifies zero unrecovered errors.
pub fn run_fleet(artifacts: Vec<PolicyArtifact>, cfg: &FleetConfig)
                 -> Result<FleetReport> {
    cfg.validate()?;
    anyhow::ensure!(!artifacts.is_empty(), "fleet needs >= 1 artifact");

    // stage the registry in a private dir: hot-reload injection
    // republishes artifacts, and user artifact dirs must not be touched
    let stage = std::env::temp_dir().join(format!(
        "qcontrol_fleet_{}_{}", std::process::id(),
        STAGE_SEQ.fetch_add(1, Ordering::Relaxed)));
    let _ = std::fs::remove_dir_all(&stage);
    std::fs::create_dir_all(&stage)
        .with_context(|| format!("creating stage dir {}",
                                 stage.display()))?;
    let result = run_staged(&artifacts, cfg, &stage);
    let _ = std::fs::remove_dir_all(&stage);
    result
}

fn run_staged(artifacts: &[PolicyArtifact], cfg: &FleetConfig,
              stage: &std::path::Path) -> Result<FleetReport> {
    for art in artifacts {
        art.save(stage.join(format!("{}.qpol", art.id)))?;
    }
    let registry = PolicyRegistry::load_dir(stage)?;
    let default_id = registry.default_id(cfg.default_policy.as_deref())?;
    let dims: BTreeMap<String, (usize, usize)> = registry
        .iter()
        .map(|(id, a)| (id.to_string(),
                        (a.policy.obs_dim, a.policy.act_dim)))
        .collect();
    let default_art = registry
        .get(&default_id)
        .expect("default id is registered")
        .clone();

    // population against the run env (explicit override, else the
    // default artifact's recorded training env)
    let env = match &cfg.env {
        Some(e) => e.clone(),
        None => {
            anyhow::ensure!(!default_art.env.is_empty(),
                            "artifact `{default_id}` does not record an \
                             env; pass one explicitly");
            default_art.env.clone()
        }
    };
    let mut pop = Population::parse(&cfg.spec, &env)?;
    if pop.normalized {
        eprintln!("fleet: population weights do not sum to 100% — \
                   normalized to relative fractions");
    }
    pop.allocate(cfg.episodes)?;
    for c in &pop.cohorts {
        if let Some(p) = &c.policy {
            anyhow::ensure!(dims.contains_key(p),
                            "cohort `{}` routes to policy `{p}`, which \
                             is not in the registry (have: {})",
                            c.label, registry.ids().join(", "));
        }
    }

    // self-hosted server on an ephemeral loopback port, ops plane
    // attached: watcher on the stage dir, monitor pre-bound so we know
    // its port before serving starts
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let mon_listener = Arc::new(TcpListener::bind("127.0.0.1:0")?);
    let mon_addr = mon_listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let server_cfg = ServerConfig {
        // workers hold one connection each; +margin for the reload
        // probe and block-boundary connection churn
        max_connections: cfg.jobs + 8,
        max_batch: cfg.max_batch,
        default_policy: Some(default_id.clone()),
        ops: OpsConfig {
            watch_dir: Some(stage.to_path_buf()),
            reload_poll: Duration::from_millis(5),
            monitor: Some(mon_listener),
            monitor_tick: Duration::from_millis(50),
            ..OpsConfig::default()
        },
        ..ServerConfig::default()
    };
    let server = {
        let stop = stop.clone();
        std::thread::Builder::new()
            .name("qfleet-server".to_string())
            .spawn(move || serve_registry(listener, registry, stop,
                                          server_cfg))
            .context("spawn fleet server")?
    };

    // monitor capture: merge the diff stream for the whole run; the
    // thread exits when the hub closes its stream at server shutdown
    let summary = Arc::new(Mutex::new(MonitorSummary::default()));
    let monitor = {
        let summary = summary.clone();
        std::thread::Builder::new()
            .name("qfleet-monitor".to_string())
            .spawn(move || {
                let Ok(mut client) = MonitorClient::connect(&mon_addr)
                else {
                    return;
                };
                while let Ok(frame) = client.recv() {
                    summary.lock().unwrap().merge(&frame);
                }
            })
            .context("spawn monitor capture")?
    };

    // the run itself: scoped worker pool + reload injector
    let drive_result = drive(cfg, &pop, &addr, &default_id, &default_art,
                             &dims, stage);

    // shutdown in dependency order: server (joins its own cores and
    // ops threads), then the capture thread the hub just released
    stop.store(true, Ordering::Relaxed);
    let stats = server
        .join()
        .map_err(|_| anyhow::anyhow!("fleet server thread panicked"))??;
    let _ = monitor.join();
    let (returns, counters, injected_reloads) = drive_result?;

    anyhow::ensure!(stats.io_errors == 0,
                    "fleet run saw {} server-side io error(s); injected \
                     faults must stay client-visible-clean",
                    stats.io_errors);

    let cohorts: Vec<CohortReport> = pop
        .cohorts
        .iter()
        .zip(returns)
        .map(|(c, r)| CohortReport::new(c, r))
        .collect();
    let monitor_summary = summary.lock().unwrap().clone();
    Ok(FleetReport {
        env,
        spec: cfg.spec.clone(),
        episodes: cfg.episodes,
        block: cfg.block,
        jobs: cfg.jobs,
        seed: cfg.seed,
        cohorts,
        counters,
        injected_reloads,
        server: stats,
        monitor: monitor_summary,
    })
}

type DriveOut = (Vec<Vec<f64>>, RemoteCounters, u64);

/// Worker pool + reload injector, scoped so borrows of the population
/// and artifact suffice. Returns per-cohort returns (episode-indexed),
/// aggregated client counters, and the confirmed injected reload count.
fn drive(cfg: &FleetConfig, pop: &Population, addr: &str,
         default_id: &str, default_art: &PolicyArtifact,
         dims: &BTreeMap<String, (usize, usize)>,
         stage: &std::path::Path) -> Result<DriveOut> {
    let queue: Mutex<VecDeque<(usize, usize, usize)>> =
        Mutex::new(pop.blocks(cfg.block).into());
    let returns: Mutex<Vec<Vec<f64>>> = Mutex::new(
        pop.cohorts.iter().map(|c| vec![0.0; c.episodes]).collect());
    let counters: Mutex<RemoteCounters> =
        Mutex::new(RemoteCounters::default());

    let worker = || -> Result<()> {
        loop {
            let Some((ci, bi, n)) = queue.lock().unwrap().pop_front()
            else {
                return Ok(());
            };
            let cohort = &pop.cohorts[ci];
            let policy = cohort.policy.as_deref().unwrap_or(default_id);
            let &(obs_dim, act_dim) = dims
                .get(policy)
                .expect("cohort policies validated against registry");
            let mut venv = VecEnv::new(|| cohort.scenario.build(), n)
                .with_context(|| format!("cohort `{}`", cohort.label))?;
            let mut backend = RemoteBackend::connect(
                addr, cohort.policy.as_deref().unwrap_or(""), obs_dim,
                act_dim, cfg.client.clone(), cfg.faults.clone())?;
            let seed = block_seed(cfg.seed, &cohort.label, bi);
            let r = venv
                .rollout_returns(&mut backend, n, seed)
                .with_context(|| {
                    format!("cohort `{}` block {bi}", cohort.label)
                })?;
            let start = bi * cfg.block;
            returns.lock().unwrap()[ci][start..start + n]
                .copy_from_slice(&r);
            counters.lock().unwrap().absorb(&backend.counters());
        }
    };

    let injected = std::thread::scope(|s| -> Result<u64> {
        let reloader = if cfg.reloads > 0 {
            Some(s.spawn(|| inject_reloads(cfg.reloads, addr, default_id,
                                           default_art, stage)))
        } else {
            None
        };
        let handles: Vec<_> =
            (0..cfg.jobs).map(|_| s.spawn(worker)).collect();
        let mut first_err = None;
        for h in handles {
            let r = h.join()
                .map_err(|_| anyhow::anyhow!("fleet worker panicked"))
                .and_then(|r| r);
            if let Err(e) = r {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        let injected = match reloader {
            Some(h) => h
                .join()
                .map_err(|_| anyhow::anyhow!("reload injector panicked"))
                .and_then(|r| r)?,
            None => 0,
        };
        match first_err {
            Some(e) => Err(e),
            None => Ok(injected),
        }
    })?;

    Ok((returns.into_inner().unwrap(), counters.into_inner().unwrap(),
        injected))
}

/// Republish the default policy `n` times under changed env tags
/// (tmp+rename — the publication idiom the watcher expects; distinct
/// tag lengths defeat coarse mtime). Each swap is confirmed through
/// the wire via the v3 version stamp before the next, so every
/// publication lands as exactly one reload *during* the run. The
/// weights are unchanged, keeping fleet results bit-identical.
fn inject_reloads(n: u64, addr: &str, default_id: &str,
                  art: &PolicyArtifact, stage: &std::path::Path)
                  -> Result<u64> {
    // let the population ramp up before the first swap
    std::thread::sleep(Duration::from_millis(30));
    let mut probe = RoutedClient::connect(addr)?;
    let obs = vec![0.0f32; art.policy.obs_dim];
    for k in 2..=(n + 1) {
        let mut next = art.clone();
        next.env = "x".repeat(k as usize);
        let tmp = stage.join(format!("{default_id}.qpol.tmp"));
        std::fs::write(&tmp, next.to_bytes()?)?;
        std::fs::rename(&tmp, stage.join(format!("{default_id}.qpol")))?;
        loop {
            let (_, v) = probe
                .act_versioned(default_id, &obs)
                .context("reload probe")?;
            if v >= k {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    Ok(n)
}
