//! The fleet population spec: weighted cohorts of scenario-wrapped
//! simulated users.
//!
//! ## Grammar
//!
//! ```text
//! population := cohort ( (',' | whitespace) cohort )*
//! cohort     := weight '%'? '=' scenario-suffix ( '@' policy-id )?
//! ```
//!
//! e.g. `70%=nominal 20%=sensor-noise 10%=sim2real`, or with explicit
//! routing, `50%=nominal@pend 50%=obsnoise:0.2@pend_v2`. The scenario
//! part is a PR-4 suffix (preset name or `+`-joined atom list) applied
//! to the run's environment; the optional `@policy-id` routes the
//! cohort's requests to that registry policy instead of the server
//! default.
//!
//! Weights are relative: they *should* sum to 100, and a spec that does
//! not is normalized (the [`Population::normalized`] flag lets the CLI
//! warn). Duplicate cohort labels are rejected, and every parse error
//! names the offending cohort — the spec is user input, so failures are
//! descriptive errors, never panics.
//!
//! ## Determinism
//!
//! Episode allocation ([`Population::allocate`]) is largest-remainder
//! and wholly deterministic, and every rollout block's RNG seed is
//! derived by FNV-1a from `(fleet seed, cohort label, block index)`
//! ([`block_seed`]) — so a fleet run is a pure function of
//! `(spec, seed, episodes, block size)`, reproducible at any
//! concurrency.

use anyhow::{Context, Result};

use crate::envs::Scenario;
use crate::experiment::fnv1a64;

/// One weighted cohort of the population.
#[derive(Clone, Debug)]
pub struct Cohort {
    /// the spec token after the weight (scenario suffix + optional
    /// `@policy`); unique within a population
    pub label: String,
    /// normalized weight fraction in (0, 1]
    pub weight: f64,
    /// fully parsed evaluation condition
    pub scenario: Scenario,
    /// registry policy id; `None` = the server default
    pub policy: Option<String>,
    /// episodes allocated by [`Population::allocate`] (0 until then)
    pub episodes: usize,
}

/// A parsed population spec against one environment.
#[derive(Clone, Debug)]
pub struct Population {
    pub env: String,
    pub cohorts: Vec<Cohort>,
    /// true when the spec weights did not sum to 100 and were rescaled
    pub normalized: bool,
}

/// Deterministic per-block rollout seed: FNV-1a over the fleet seed,
/// the cohort label, and the block index. Independent of `--jobs`,
/// worker scheduling, and cohort order.
pub fn block_seed(fleet_seed: u64, cohort_label: &str, block: usize)
                  -> u64 {
    fnv1a64(&format!("{fleet_seed}|{cohort_label}|{block}"))
}

impl Population {
    /// Parse a population spec against `env`. Cohorts may be separated
    /// by commas and/or whitespace.
    pub fn parse(spec: &str, env: &str) -> Result<Population> {
        let tokens: Vec<&str> = spec
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|t| !t.is_empty())
            .collect();
        anyhow::ensure!(!tokens.is_empty(),
                        "empty population spec (expected e.g. \
                         `70%=nominal 30%=sensor-noise`)");
        let mut cohorts = Vec::with_capacity(tokens.len());
        for tok in &tokens {
            cohorts.push(parse_cohort(tok, env)?);
        }
        for i in 1..cohorts.len() {
            let label = &cohorts[i].label;
            anyhow::ensure!(
                cohorts[..i].iter().all(|c| &c.label != label),
                "duplicate cohort `{label}` in population spec (labels \
                 are the part after `=`; merge the weights instead)");
        }
        let sum: f64 = cohorts.iter().map(|c| c.weight).sum();
        anyhow::ensure!(sum > 0.0, "population weights sum to 0");
        let normalized = (sum - 100.0).abs() > 1e-6;
        for c in &mut cohorts {
            c.weight /= sum;
        }
        Ok(Population { env: env.to_string(), cohorts, normalized })
    }

    /// Split `total` episodes across the cohorts by weight
    /// (largest-remainder rounding, ties broken by cohort order), then
    /// guarantee every cohort at least one episode — a cohort the user
    /// asked for must contribute to the report. Requires
    /// `total >= cohorts`.
    pub fn allocate(&mut self, total: usize) -> Result<()> {
        let n = self.cohorts.len();
        anyhow::ensure!(total >= n,
                        "{total} episode(s) cannot cover {n} cohort(s) \
                         with at least one episode each");
        let mut rem: Vec<(usize, f64)> = Vec::with_capacity(n);
        let mut assigned = 0usize;
        for (i, c) in self.cohorts.iter_mut().enumerate() {
            let quota = c.weight * total as f64;
            c.episodes = quota.floor() as usize;
            assigned += c.episodes;
            rem.push((i, quota - quota.floor()));
        }
        // largest fractional remainder first; equal remainders keep
        // cohort order (stable sort on the negated remainder)
        rem.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap()
                    .then(a.0.cmp(&b.0)));
        for &(i, _) in rem.iter().take(total - assigned) {
            self.cohorts[i].episodes += 1;
        }
        // floor can strand a tiny cohort at 0: take from the largest
        while let Some(zero) =
            self.cohorts.iter().position(|c| c.episodes == 0)
        {
            let donor = self
                .cohorts
                .iter()
                .enumerate()
                .max_by_key(|(_, c)| c.episodes)
                .map(|(i, _)| i)
                .expect("population has cohorts");
            anyhow::ensure!(self.cohorts[donor].episodes > 1,
                            "cannot give every cohort an episode");
            self.cohorts[donor].episodes -= 1;
            self.cohorts[zero].episodes += 1;
        }
        debug_assert_eq!(
            self.cohorts.iter().map(|c| c.episodes).sum::<usize>(), total);
        Ok(())
    }

    /// Every `(cohort index, block index, episodes in block)` rollout
    /// unit, in deterministic order. Each block is one independent
    /// `VecEnv::rollout_returns` call of at most `block` episodes,
    /// seeded by [`block_seed`] — the unit of work-stealing that keeps
    /// fleet results identical at any `--jobs`.
    pub fn blocks(&self, block: usize) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::new();
        for (ci, c) in self.cohorts.iter().enumerate() {
            let mut left = c.episodes;
            let mut bi = 0usize;
            while left > 0 {
                let n = left.min(block.max(1));
                out.push((ci, bi, n));
                left -= n;
                bi += 1;
            }
        }
        out
    }
}

/// Parse one `weight[%]=suffix[@policy]` token.
fn parse_cohort(tok: &str, env: &str) -> Result<Cohort> {
    let (w, rest) = tok.split_once('=').with_context(|| {
        format!("cohort `{tok}` is not `WEIGHT%=SCENARIO[@policy]`")
    })?;
    let w = w.strip_suffix('%').unwrap_or(w);
    let weight: f64 = w
        .parse()
        .with_context(|| format!("cohort `{tok}`: bad weight `{w}`"))?;
    anyhow::ensure!(weight.is_finite() && weight > 0.0,
                    "cohort `{tok}`: weight must be finite and > 0, \
                     got {weight}");
    anyhow::ensure!(!rest.is_empty(),
                    "cohort `{tok}` has an empty scenario part");
    let (suffix, policy) = match rest.split_once('@') {
        Some((s, p)) => {
            anyhow::ensure!(!p.is_empty(),
                            "cohort `{tok}` has an empty policy id \
                             after `@`");
            (s, Some(p.to_string()))
        }
        None => (rest, None),
    };
    let scenario = Scenario::parse_suffix(env, suffix)
        .with_context(|| format!("cohort `{rest}`: bad scenario \
                                  `{suffix}`"))?;
    Ok(Cohort {
        label: rest.to_string(),
        weight,
        scenario,
        policy,
        episodes: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let p = Population::parse("70%=nominal 20%=sensor-noise \
                                   10%=sim2real", "pendulum").unwrap();
        assert_eq!(p.cohorts.len(), 3);
        assert!(!p.normalized);
        assert!((p.cohorts[0].weight - 0.7).abs() < 1e-12);
        assert!(p.cohorts[0].scenario.is_bare());
        assert_eq!(p.cohorts[1].scenario.suffix(), "obsnoise:0.1");
        assert!(p.cohorts.iter().all(|c| c.policy.is_none()));
    }

    #[test]
    fn comma_separation_policy_routing_and_normalization() {
        let p = Population::parse("3=obsnoise:0.2@alt,1=nominal",
                                  "pendulum").unwrap();
        assert!(p.normalized); // 3 + 1 != 100 — rescaled
        assert!((p.cohorts[0].weight - 0.75).abs() < 1e-12);
        assert_eq!(p.cohorts[0].policy.as_deref(), Some("alt"));
        assert_eq!(p.cohorts[0].label, "obsnoise:0.2@alt");
        assert_eq!(p.cohorts[1].policy, None);
    }

    #[test]
    fn errors_name_the_offending_cohort() {
        let err = Population::parse("50%=nominal 50%=obsnoise:-1",
                                    "pendulum").unwrap_err();
        assert!(format!("{err:#}").contains("obsnoise:-1"), "{err:#}");
        let err = Population::parse("50%=nominal 50%=nominal",
                                    "pendulum").unwrap_err();
        assert!(err.to_string().contains("duplicate cohort `nominal`"),
                "{err}");
        let err = Population::parse("x%=nominal", "pendulum").unwrap_err();
        assert!(err.to_string().contains("x%=nominal"), "{err}");
        assert!(Population::parse("", "pendulum").is_err());
        assert!(Population::parse("50%=nominal@", "pendulum").is_err());
    }

    #[test]
    fn allocation_is_exact_and_floors_at_one() {
        let mut p = Population::parse("70%=nominal 20%=sensor-noise \
                                       10%=sim2real", "pendulum").unwrap();
        p.allocate(10).unwrap();
        let eps: Vec<usize> =
            p.cohorts.iter().map(|c| c.episodes).collect();
        assert_eq!(eps, vec![7, 2, 1]);

        // a 1% cohort still gets an episode out of 10
        let mut p = Population::parse("99%=nominal 1%=sim2real",
                                      "pendulum").unwrap();
        p.allocate(10).unwrap();
        assert_eq!(p.cohorts[1].episodes, 1);
        assert_eq!(p.cohorts[0].episodes, 9);

        // fewer episodes than cohorts is a descriptive error
        assert!(p.allocate(1).is_err());
    }

    #[test]
    fn blocks_partition_the_allocation() {
        let mut p = Population::parse("60%=nominal 40%=sensor-noise",
                                      "pendulum").unwrap();
        p.allocate(10).unwrap();
        let blocks = p.blocks(4);
        assert_eq!(blocks, vec![(0, 0, 4), (0, 1, 2), (1, 0, 4)]);
        let total: usize = blocks.iter().map(|&(_, _, n)| n).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn block_seeds_are_distinct_and_stable() {
        let a = block_seed(42, "nominal", 0);
        assert_eq!(a, block_seed(42, "nominal", 0));
        assert_ne!(a, block_seed(42, "nominal", 1));
        assert_ne!(a, block_seed(42, "sensor-noise", 0));
        assert_ne!(a, block_seed(43, "nominal", 0));
    }
}
