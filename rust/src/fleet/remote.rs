//! [`RemoteBackend`] — a [`PolicyBackend`] whose inference happens on a
//! live serving process over the v3 wire protocol.
//!
//! Each `infer_batch` row becomes one framed round-trip through a
//! [`RoutedClient`]; because the serving core is row-wise deterministic
//! and call-history-free, a resent observation yields the identical
//! action — which is what lets the fault-recovery path (reconnect +
//! resend) preserve bit-exact rollouts even while connections are being
//! dropped on purpose.
//!
//! The backend also carries the fleet's client-side fault injectors:
//! forced connection drops every N requests and delayed frames, both
//! off by default. Version stamps from v3 replies are tracked so a
//! mid-run hot reload is *observed* by the population, not just by the
//! server's own counters.
//!
//! Note on normalization: the serving core normalizes raw wire
//! observations with the artifact's frozen normalizer, so fleet
//! environments are built **without** a client-side `Normalize` layer —
//! scenario perturbations act on raw sensor readings, exactly what a
//! deployed controller would see. [`ServerMirror`] reproduces the
//! server's normalize-then-infer core in process for equivalence tests.

use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::serving::{ClientConfig, RoutedClient};
use crate::intinfer::IntEngine;
use crate::policy::{check_block, PolicyArtifact, PolicyBackend,
                    PolicyDescriptor};
use crate::util::stats::ObsNormalizer;

/// Client-side fault injection knobs (all off by default).
#[derive(Clone, Debug, Default)]
pub struct FaultSpec {
    /// force-close the connection every N requests (0 = never); the
    /// next request then exercises the reconnect + resend path
    pub drop_every: u64,
    /// delay one frame every N requests by `delay` (0 = never)
    pub delay_every: u64,
    /// how long a delayed frame stalls before being sent
    pub delay: Duration,
}

/// Wire/fault counters a fleet run aggregates across its backends.
#[derive(Clone, Copy, Debug, Default)]
pub struct RemoteCounters {
    pub requests: u64,
    /// connections deliberately closed by [`FaultSpec::drop_every`]
    pub forced_drops: u64,
    /// successful reconnect + resend recoveries (forced or not)
    pub recovered: u64,
    /// frames stalled by [`FaultSpec::delay_every`]
    pub delayed: u64,
    /// v3 version transitions observed mid-run (hot reloads seen)
    pub reloads_observed: u64,
}

impl RemoteCounters {
    pub fn absorb(&mut self, other: &RemoteCounters) {
        self.requests += other.requests;
        self.forced_drops += other.forced_drops;
        self.recovered += other.recovered;
        self.delayed += other.delayed;
        self.reloads_observed += other.reloads_observed;
    }
}

/// A policy backend that speaks to a live server. Dimensions are fixed
/// at construction (the fleet knows its artifacts), so a `VecEnv` can
/// shape-check before any wire traffic.
pub struct RemoteBackend {
    client: RoutedClient,
    /// id sent on the wire; `""` routes to the server default
    policy: String,
    obs_dim: usize,
    act_dim: usize,
    faults: FaultSpec,
    counters: RemoteCounters,
    last_version: Option<u64>,
}

impl RemoteBackend {
    pub fn connect(addr: &str, policy: &str, obs_dim: usize,
                   act_dim: usize, cfg: ClientConfig, faults: FaultSpec)
                   -> Result<RemoteBackend> {
        let client = RoutedClient::connect_with(addr, cfg)?;
        Ok(RemoteBackend {
            client,
            policy: policy.to_string(),
            obs_dim,
            act_dim,
            faults,
            counters: RemoteCounters::default(),
            last_version: None,
        })
    }

    pub fn counters(&self) -> RemoteCounters {
        self.counters
    }

    /// Latest v3 version stamp seen from the server (None before the
    /// first reply).
    pub fn version(&self) -> Option<u64> {
        self.last_version
    }

    /// One recoverable round-trip: on any failure, repair the
    /// connection (bounded retry with backoff) and resend once. A
    /// second failure is an unrecovered error and bubbles up.
    fn round_trip(&mut self, obs: &[f32]) -> Result<Vec<f32>> {
        self.counters.requests += 1;
        if self.faults.drop_every > 0
            && self.counters.requests % self.faults.drop_every == 0
        {
            self.client.force_disconnect();
            self.counters.forced_drops += 1;
        }
        if self.faults.delay_every > 0
            && self.counters.requests % self.faults.delay_every == 0
            && !self.faults.delay.is_zero()
        {
            std::thread::sleep(self.faults.delay);
            self.counters.delayed += 1;
        }
        let (act, version) =
            match self.client.act_versioned(&self.policy, obs) {
                Ok(r) => r,
                Err(first) => {
                    self.client.reconnect().with_context(|| {
                        format!("unrecovered: request failed ({first:#}) \
                                 and reconnect did not succeed")
                    })?;
                    let r = self
                        .client
                        .act_versioned(&self.policy, obs)
                        .with_context(|| {
                            format!("unrecovered: resend after reconnect \
                                     failed (original error: {first:#})")
                        })?;
                    self.counters.recovered += 1;
                    r
                }
            };
        if let Some(prev) = self.last_version {
            if version != prev {
                self.counters.reloads_observed += 1;
            }
        }
        self.last_version = Some(version);
        Ok(act)
    }
}

impl PolicyBackend for RemoteBackend {
    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn act_dim(&self) -> usize {
        self.act_dim
    }

    fn infer_batch(&mut self, obs: &[f32], actions_out: &mut [f32])
                   -> Result<()> {
        let batch = check_block(obs, actions_out, self.obs_dim,
                                self.act_dim)?;
        for row in 0..batch {
            let o = &obs[row * self.obs_dim..(row + 1) * self.obs_dim];
            let act = self.round_trip(o)?;
            anyhow::ensure!(act.len() == self.act_dim,
                            "server returned {} action values, policy \
                             `{}` expects {}", act.len(),
                            if self.policy.is_empty() { "(default)" }
                            else { self.policy.as_str() }, self.act_dim);
            actions_out[row * self.act_dim..(row + 1) * self.act_dim]
                .copy_from_slice(&act);
        }
        Ok(())
    }

    /// Unknown from the wire (weights live server-side).
    fn macs(&self) -> u64 {
        0
    }

    fn descriptor(&self) -> PolicyDescriptor {
        PolicyDescriptor {
            id: if self.policy.is_empty() {
                "(default)".to_string()
            } else {
                self.policy.clone()
            },
            kind: "remote",
            obs_dim: self.obs_dim,
            act_dim: self.act_dim,
            hidden: 0,
            bits: None,
        }
    }
}

/// In-process replica of one serving core: normalize each raw
/// observation row with the artifact's frozen normalizer, then run the
/// same optimized integer engine the server compiles. A `VecEnv`
/// rollout through a `ServerMirror` is the bit-exact reference for the
/// same rollout through a [`RemoteBackend`].
pub struct ServerMirror {
    engine: IntEngine,
    norm: ObsNormalizer,
    scratch: Vec<f32>,
}

impl ServerMirror {
    pub fn new(artifact: &PolicyArtifact) -> Result<ServerMirror> {
        Ok(ServerMirror {
            engine: IntEngine::optimized(artifact.policy.clone())?,
            norm: artifact.normalizer(),
            scratch: Vec::new(),
        })
    }
}

impl PolicyBackend for ServerMirror {
    fn obs_dim(&self) -> usize {
        self.engine.obs_dim()
    }

    fn act_dim(&self) -> usize {
        self.engine.act_dim()
    }

    fn infer_batch(&mut self, obs: &[f32], actions_out: &mut [f32])
                   -> Result<()> {
        self.scratch.clear();
        self.scratch.extend_from_slice(obs);
        let obs_dim = PolicyBackend::obs_dim(&self.engine);
        for lane in self.scratch.chunks_exact_mut(obs_dim) {
            self.norm.normalize(lane);
        }
        // the trait method (the inherent `IntEngine::infer_batch`
        // asserts on dim errors instead of returning them)
        PolicyBackend::infer_batch(&mut self.engine, &self.scratch,
                                   actions_out)
    }

    fn macs(&self) -> u64 {
        self.engine.macs()
    }

    fn descriptor(&self) -> PolicyDescriptor {
        let mut d = self.engine.descriptor();
        d.kind = "mirror";
        d
    }
}
