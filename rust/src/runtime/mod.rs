//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the rust hot path. Python never runs here.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifacts are lowered with `return_tuple=True`, so every execution
//! returns one tuple literal that we decompose.
//!
//! Executables are compiled lazily and cached per artifact name; a process
//! typically touches a handful of the 100+ artifacts in the manifest.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

// The `xla` PJRT bindings are optional: they need native XLA libraries that
// offline build environments don't have. Without the `pjrt` feature an
// inert stub with the same surface takes their place — `Runtime::load`
// fails with a clear message and nothing else is reachable, while the rest
// of the crate (quantization, integer inference, serving) builds and runs.
#[cfg(feature = "pjrt")]
use xla::{
    ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};
#[cfg(not(feature = "pjrt"))]
use pjrt_stub::{
    ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};
#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;

pub use manifest::{ArtifactMeta, EnvDims, Manifest, ParamSpec, SpecEntry};

/// Shared PJRT runtime over one artifact directory.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Exe>>>,
    /// cumulative compile time (reported by `qcontrol info`)
    pub compile_secs: Mutex<f64>,
}

/// A compiled executable plus its manifest signature.
pub struct Exe {
    raw: PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

impl Runtime {
    /// Load the manifest and create the CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: Mutex::new(HashMap::new()),
            compile_secs: Mutex::new(0.0),
        })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn exe(&self, name: &str) -> Result<Arc<Exe>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact `{name}`"))?
            .clone();
        let t0 = Instant::now();
        let path = meta.file.to_string_lossy().to_string();
        let proto = HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {path}: {e}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let raw = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        let exe = Arc::new(Exe { raw, meta });
        *self.compile_secs.lock().unwrap() += t0.elapsed().as_secs_f64();
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Structured lookup + compile.
    pub fn exe_for(&self, algo: &str, kind: &str, env: &str, hidden: usize,
                   batch: Option<usize>) -> Result<Arc<Exe>> {
        let meta = self.manifest.artifact(algo, kind, env, hidden, batch)?;
        let name = meta.name.clone();
        self.exe(&name)
    }
}

impl Exe {
    /// Execute with f32 host buffers; returns the decomposed output tuple
    /// as host `Vec<f32>`s, in manifest output order.
    ///
    /// Input shapes are validated against the manifest signature — a
    /// mismatch is a bug in the caller, reported with the tensor name.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!("{}: expected {} inputs, got {}",
                  self.meta.name, self.meta.inputs.len(), inputs.len());
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (sig, data) in self.meta.inputs.iter().zip(inputs) {
            if sig.numel() != data.len() {
                bail!("{}: input `{}` expects {} elements ({:?}), got {}",
                      self.meta.name, sig.name, sig.numel(), sig.shape,
                      data.len());
            }
            // single-copy literal creation (vec1+reshape would copy twice;
            // measured in EXPERIMENTS.md §Perf)
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                           data.len() * 4)
            };
            let lit = Literal::create_from_shape_and_untyped_data(
                ElementType::F32, &sig.shape, bytes)
                .map_err(|e| anyhow::anyhow!("literal: {e}"))?;
            lits.push(lit);
        }
        let out = self
            .raw
            .execute::<Literal>(&lits)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.meta.name))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        if parts.len() != self.meta.outputs.len() {
            bail!("{}: expected {} outputs, got {}",
                  self.meta.name, self.meta.outputs.len(), parts.len());
        }
        let mut res = Vec::with_capacity(parts.len());
        for (sig, p) in self.meta.outputs.iter().zip(parts) {
            let p = if p.element_type()
                .map(|t| t != ElementType::F32)
                .unwrap_or(false)
            {
                p.convert(ElementType::F32.primitive_type())
                    .map_err(|e| anyhow::anyhow!("convert: {e}"))?
            } else {
                p
            };
            let v = p
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec {}: {e}", sig.name))?;
            if v.len() != sig.numel() {
                bail!("{}: output `{}` numel mismatch {} vs {}",
                      self.meta.name, sig.name, v.len(), sig.numel());
            }
            res.push(v);
        }
        Ok(res)
    }
}

/// Locate the artifacts directory: `$QCONTROL_ARTIFACTS`, else ./artifacts
/// relative to the current dir or the crate root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("QCONTROL_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for base in [".", env!("CARGO_MANIFEST_DIR")] {
        let p = Path::new(base).join("artifacts");
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}
