//! Inert stand-in for the `xla` PJRT bindings, used when the crate is
//! built without the `pjrt` feature (the default in offline environments).
//!
//! Every constructor returns an error, so the executable types below are
//! uninhabited: `Runtime::load` fails up front with a clear message and no
//! method body past construction is ever reachable (`match *self {}`).
//! The surface mirrors exactly the calls `runtime::Exe::run_f32` and
//! `Runtime::exe` make against the real crate.

// empty matches on `*self` of an uninhabited type are the point here
#![allow(unknown_lints)]
#![allow(clippy::uninhabited_references)]

use std::fmt;

const MSG: &str = "qcontrol was built without the `pjrt` feature; \
                   rebuild with `--features pjrt` (and the `xla` bindings \
                   crate available) to load and execute HLO artifacts";

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct XlaError(&'static str);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(MSG))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

impl ElementType {
    pub fn primitive_type(self) -> i32 {
        // numeric tag only flows back into the stub's own `convert`
        11
    }
}

pub enum PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable, XlaError> {
        match *self {}
    }
}

pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T])
                      -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        match *self {}
    }
}

pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        match *self {}
    }
}

pub enum Literal {}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType, _shape: &[usize], _data: &[u8],
    ) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        match self {}
    }

    pub fn element_type(&self) -> Result<ElementType, XlaError> {
        match *self {}
    }

    pub fn convert(&self, _primitive: i32) -> Result<Literal, XlaError> {
        match *self {}
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        match *self {}
    }
}

pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// Placeholder computation handle (constructible, but only from an
/// uninhabited proto, so it can never actually exist at runtime).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
