//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and this runtime. Mirrors `python/compile/hyper.py` and
//! `python/compile/params.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One entry of a flat parameter vector layout.
#[derive(Clone, Debug)]
pub struct SpecEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub group: String,
}

/// Flat-vector layout of one (algo, env, hidden) model.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub n_params: usize,
    pub entries: Vec<SpecEntry>,
}

impl ParamSpec {
    pub fn find(&self, name: &str) -> Result<&SpecEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no param entry `{name}`"))
    }

    /// Borrow the slice of `flat` occupied by entry `name`.
    pub fn slice<'a>(&self, flat: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let e = self.find(name)?;
        Ok(&flat[e.offset..e.offset + e.size])
    }

    pub fn scalar(&self, flat: &[f32], name: &str) -> Result<f32> {
        let e = self.find(name)?;
        if e.size != 1 {
            bail!("`{name}` is not scalar");
        }
        Ok(flat[e.offset])
    }
}

/// Tensor signature of an artifact input/output.
#[derive(Clone, Debug)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One AOT-compiled executable.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub kind: String, // train | act | fwd
    pub algo: String, // sac | ddpg
    pub env: String,
    pub hidden: usize,
    pub batch: usize,
    pub spec_key: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Environment dimensionalities as seen by the compile path.
#[derive(Clone, Copy, Debug)]
pub struct EnvDims {
    pub obs_dim: usize,
    pub act_dim: usize,
}

/// Parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub hyper: BTreeMap<String, usize>,
    pub hyper_len: usize,
    pub metrics: BTreeMap<String, usize>,
    pub metric_len: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub envs: BTreeMap<String, EnvDims>,
    pub specs: BTreeMap<String, ParamSpec>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Manifest> {
        let idx_map = |v: &Json| -> Result<BTreeMap<String, usize>> {
            v.as_obj()?
                .iter()
                .map(|(k, x)| Ok((k.clone(), x.as_usize()?)))
                .collect()
        };
        let mut envs = BTreeMap::new();
        for (k, v) in j.get("envs")?.as_obj()? {
            envs.insert(k.clone(), EnvDims {
                obs_dim: v.get("obs_dim")?.as_usize()?,
                act_dim: v.get("act_dim")?.as_usize()?,
            });
        }
        let mut specs = BTreeMap::new();
        for (k, v) in j.get("specs")?.as_obj()? {
            let entries = v
                .get("entries")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(SpecEntry {
                        name: e.get("name")?.as_str()?.to_string(),
                        shape: e.get("shape")?.as_usize_vec()?,
                        offset: e.get("offset")?.as_usize()?,
                        size: e.get("size")?.as_usize()?,
                        group: e.get("group")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            specs.insert(k.clone(), ParamSpec {
                n_params: v.get("n_params")?.as_usize()?,
                entries,
            });
        }
        let sig = |v: &Json| -> Result<Vec<TensorSig>> {
            v.as_arr()?
                .iter()
                .map(|t| {
                    Ok(TensorSig {
                        name: t.get("name")?.as_str()?.to_string(),
                        shape: t.get("shape")?.as_usize_vec()?,
                    })
                })
                .collect()
        };
        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts")?.as_arr()? {
            let name = a.get("name")?.as_str()?.to_string();
            artifacts.insert(name.clone(), ArtifactMeta {
                name,
                file: dir.join(a.get("file")?.as_str()?),
                kind: a.get("kind")?.as_str()?.to_string(),
                algo: a.get("algo")?.as_str()?.to_string(),
                env: a.get("env")?.as_str()?.to_string(),
                hidden: a.get("hidden")?.as_usize()?,
                batch: a.get("batch")?.as_usize()?,
                spec_key: a.get("spec")?.as_str()?.to_string(),
                inputs: sig(a.get("inputs")?)?,
                outputs: sig(a.get("outputs")?)?,
            });
        }
        let m = Manifest {
            hyper: idx_map(j.get("hyper")?)?,
            hyper_len: j.get("hyper_len")?.as_usize()?,
            metrics: idx_map(j.get("metrics")?)?,
            metric_len: j.get("metric_len")?.as_usize()?,
            train_batch: j.get("train_batch")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            envs,
            specs,
            artifacts,
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        for (name, a) in &self.artifacts {
            if !self.specs.contains_key(&a.spec_key) {
                bail!("artifact {name} references unknown spec {}",
                      a.spec_key);
            }
            if !self.envs.contains_key(&a.env) {
                bail!("artifact {name} references unknown env {}", a.env);
            }
        }
        for spec in self.specs.values() {
            let mut cursor = 0;
            for e in &spec.entries {
                if e.offset != cursor {
                    bail!("spec has holes at `{}`", e.name);
                }
                cursor += e.size;
            }
            if cursor != spec.n_params {
                bail!("spec total mismatch: {} != {}", cursor, spec.n_params);
            }
        }
        Ok(())
    }

    /// Artifact lookup by structured key.
    pub fn artifact(&self, algo: &str, kind: &str, env: &str, hidden: usize,
                    batch: Option<usize>) -> Result<&ArtifactMeta> {
        let name = match (kind, batch) {
            ("fwd", Some(b)) => format!("{algo}_fwd_{env}_h{hidden}_b{b}"),
            _ => format!("{algo}_{kind}_{env}_h{hidden}"),
        };
        self.artifacts
            .get(&name)
            .ok_or_else(|| anyhow!(
                "artifact `{name}` not in manifest (available widths for \
                 {env}: {:?})",
                self.artifacts
                    .values()
                    .filter(|a| a.env == env && a.algo == algo
                            && a.kind == kind)
                    .map(|a| a.hidden)
                    .collect::<Vec<_>>()))
    }

    pub fn hyper_idx(&self, name: &str) -> usize {
        *self.hyper.get(name).unwrap_or_else(|| {
            panic!("hyper field `{name}` missing from manifest")
        })
    }

    pub fn metric_idx(&self, name: &str) -> usize {
        *self.metrics.get(name).unwrap_or_else(|| {
            panic!("metric field `{name}` missing from manifest")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest_json() -> String {
        r#"{
          "hyper": {"step": 0, "b_in": 7}, "hyper_len": 16,
          "metrics": {"qf1_loss": 0}, "metric_len": 16,
          "train_batch": 256, "eval_batch": 16,
          "envs": {"pendulum": {"obs_dim": 3, "act_dim": 1}},
          "specs": {"sac_pendulum_h16": {"n_params": 10, "entries": [
            {"name": "a.w", "shape": [2,3], "offset": 0, "size": 6,
             "group": "actor"},
            {"name": "a.b", "shape": [3], "offset": 6, "size": 3,
             "group": "actor"},
            {"name": "s", "shape": [], "offset": 9, "size": 1,
             "group": "scale"}]}},
          "artifacts": [
            {"name": "sac_train_pendulum_h16",
             "file": "sac_train_pendulum_h16.hlo.txt",
             "kind": "train", "algo": "sac", "env": "pendulum",
             "hidden": 16, "batch": 256, "spec": "sac_pendulum_h16",
             "inputs": [{"name": "params", "shape": [10]}],
             "outputs": [{"name": "params", "shape": [10]}],
             "sha256": "x"}]
        }"#
        .to_string()
    }

    #[test]
    fn parses_toy_manifest() {
        let j = json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(&j, Path::new("/tmp")).unwrap();
        assert_eq!(m.hyper_idx("b_in"), 7);
        assert_eq!(m.envs["pendulum"].obs_dim, 3);
        let a = m.artifact("sac", "train", "pendulum", 16, None).unwrap();
        assert_eq!(a.batch, 256);
        let spec = &m.specs[&a.spec_key];
        assert_eq!(spec.find("a.b").unwrap().offset, 6);
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        assert_eq!(spec.slice(&flat, "a.b").unwrap(), &[6.0, 7.0, 8.0]);
        assert_eq!(spec.scalar(&flat, "s").unwrap(), 9.0);
    }

    #[test]
    fn validation_catches_holes() {
        let bad = toy_manifest_json().replace(
            r#""offset": 6"#, r#""offset": 7"#);
        let j = json::parse(&bad).unwrap();
        assert!(Manifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn missing_artifact_reports_alternatives() {
        let j = json::parse(&toy_manifest_json()).unwrap();
        let m = Manifest::from_json(&j, Path::new("/tmp")).unwrap();
        let err = m.artifact("sac", "train", "pendulum", 999, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("16"), "{err}");
    }
}
