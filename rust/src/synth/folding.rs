//! Throughput-driven folding search (paper §3.4).
//!
//! For each target throughput (actions/s), choose per-layer PE/SIMD so every
//! layer's cycle count ≤ clock/target, minimizing resources; then keep the
//! *highest* power-of-10 target whose design fits the device and meets
//! timing. This mirrors FINN's `target_fps` flow plus the paper's retained
//! highest completing build.
//!
//! The search consumes the [`LayerGeom`] rows the IR's typed edges
//! provide ([`super::model::layer_geometry`] over a verified
//! [`QGraph`]); `fold_geometry`/`search_geometry` stay geometry-level so
//! callers with a hand-built geometry (tests, what-if sweeps) can drive
//! the identical cost path.

use anyhow::{bail, Result};

use super::model::{cost_layer, layer_geometry, Design, Device,
                   LayerFold, LayerGeom};
use crate::qir::QGraph;

/// Divisors of n, ascending.
fn divisors(n: usize) -> Vec<usize> {
    let mut d: Vec<usize> = (1..=n).filter(|k| n % k == 0).collect();
    d.sort_unstable();
    d
}

#[derive(Clone, Debug)]
pub struct FoldingChoice {
    pub folds: Vec<LayerFold>,
    pub target_throughput: f64,
}

#[derive(Debug)]
pub struct SearchOutcome {
    pub design: Design,
    pub choice: FoldingChoice,
    /// all targets attempted, with fit/timing verdicts (for reports)
    pub attempts: Vec<(f64, bool, bool)>,
}

/// Minimal-resource folding for one layer meeting a cycle budget, or None.
fn fold_layer_for_budget(geom: &LayerGeom, budget_cycles: u64,
                         dsps_avail: u64)
                         -> Option<super::model::MvauCost> {
    let mut best: Option<super::model::MvauCost> = None;
    for &pe in &divisors(geom.rows) {
        for &simd in &divisors(geom.cols) {
            let cycles =
                (geom.rows / pe) as u64 * (geom.cols / simd) as u64;
            if cycles > budget_cycles {
                continue;
            }
            let c = cost_layer(geom.rows, geom.cols,
                               LayerFold { pe, simd }, geom.w_bits,
                               geom.in_bits, geom.out_bits,
                               geom.acc_bits, dsps_avail);
            let better = match &best {
                None => true,
                Some(b) => (c.luts + c.dsps * 40,
                            (c.bram36 * 16.0) as u64)
                    < (b.luts + b.dsps * 40, (b.bram36 * 16.0) as u64),
            };
            if better {
                best = Some(c);
            }
        }
    }
    best
}

/// Fold a geometry for one throughput target.
pub fn fold_geometry(geoms: &[LayerGeom], device: &Device, clock_hz: f64,
                     target: f64) -> Option<Design> {
    let budget = (clock_hz / target).floor() as u64;
    if budget == 0 {
        return None;
    }
    let mut layers = Vec::new();
    let mut dsps_left = device.dsps;
    for geom in geoms {
        let c = fold_layer_for_budget(geom, budget, dsps_left)?;
        dsps_left = dsps_left.saturating_sub(c.dsps);
        layers.push(c);
    }
    Some(Design { device: *device, clock_hz, layers })
}

/// Fold a whole graph for one throughput target.
pub fn fold_for_target(g: &QGraph, device: &Device, clock_hz: f64,
                       target: f64) -> Result<Option<Design>> {
    Ok(fold_geometry(&layer_geometry(g)?, device, clock_hz, target))
}

/// The §3.4 procedure over a pre-extracted geometry: sweep powers of 10,
/// retain the best feasible build.
pub fn search_geometry(geoms: &[LayerGeom], device: &Device,
                       clock_hz: f64) -> Result<SearchOutcome> {
    let mut attempts = Vec::new();
    let mut best: Option<(f64, Design)> = None;
    for exp in 1..=8 {
        let target = 10f64.powi(exp);
        let Some(design) = fold_geometry(geoms, device, clock_hz, target)
        else {
            attempts.push((target, false, false));
            continue;
        };
        let fits = design.fits(1.0);
        let timing = design.meets_timing();
        attempts.push((target, fits, timing));
        if fits && timing {
            best = Some((target, design));
        }
    }
    match best {
        Some((target, design)) => Ok(SearchOutcome {
            design,
            choice: FoldingChoice {
                folds: Vec::new(),
                target_throughput: target,
            },
            attempts,
        }),
        None => bail!(
            "no feasible folding on {} for this graph (its smallest build \
             exceeds the device — the paper hit this with 8-bit width-256 \
             models)",
            device.name
        ),
    }
}

/// The §3.4 procedure over a verified graph.
pub fn search_folding(g: &QGraph, device: &Device, clock_hz: f64)
                      -> Result<SearchOutcome> {
    search_geometry(&layer_geometry(g)?, device, clock_hz)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::qir::{lower, QGraph};
    use crate::quant::export::IntPolicy;
    use crate::quant::fakequant::PolicyTensors;
    use crate::quant::BitCfg;
    use crate::synth::model::XC7A15T;
    use crate::util::rng::Rng;

    pub(crate) fn toy_policy(obs: usize, h: usize, act: usize,
                             bits: BitCfg) -> IntPolicy {
        let mut r = Rng::new(1);
        let mut mk = |n: usize, s: f32| -> Vec<f32> {
            let mut v = vec![0.0f32; n];
            r.fill_normal(&mut v);
            v.iter_mut().for_each(|x| *x *= s);
            v
        };
        let w1 = mk(h * obs, 0.4);
        let b1 = mk(h, 0.1);
        let w2 = mk(h * h, 0.3);
        let b2 = mk(h, 0.1);
        let w3 = mk(act * h, 0.3);
        let b3 = mk(act, 0.1);
        let p = PolicyTensors {
            obs_dim: obs, hidden: h, act_dim: act,
            fc1_w: &w1, fc1_b: &b1, fc2_w: &w2, fc2_b: &b2,
            mean_w: &w3, mean_b: &b3,
            s_in: 2.0, s_h1: 1.2, s_h2: 1.2, s_out: 1.0,
        };
        IntPolicy::from_tensors(&p, bits)
    }

    pub(crate) fn toy_graph(obs: usize, h: usize, act: usize,
                            bits: BitCfg) -> QGraph {
        lower(&toy_policy(obs, h, act, bits))
    }

    #[test]
    fn divisors_complete() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
    }

    #[test]
    fn higher_target_more_resources() {
        let g = toy_graph(11, 64, 3, BitCfg::new(4, 3, 8));
        let slow = fold_for_target(&g, &XC7A15T, 1e8, 1e3)
            .unwrap()
            .unwrap();
        let fast = fold_for_target(&g, &XC7A15T, 1e8, 1e5)
            .unwrap()
            .unwrap();
        assert!(fast.initiation_interval() <= 1_000);
        assert!(slow.initiation_interval() <= 100_000);
        assert!(fast.luts() >= slow.luts(),
                "fast {} slow {}", fast.luts(), slow.luts());
    }

    #[test]
    fn search_picks_feasible_maximum() {
        let g = toy_graph(3, 16, 1, BitCfg::new(4, 2, 8));
        let out = search_folding(&g, &XC7A15T, 1e8).unwrap();
        assert!(out.design.fits(1.0));
        assert!(out.design.meets_timing());
        assert!(out.choice.target_throughput >= 1e3);
        // at least one attempt should have failed above the chosen target
        // OR the chosen target is the sweep max
        let t = out.choice.target_throughput;
        assert!(t <= 1e8);
    }

    #[test]
    fn wide_8bit_model_rejected() {
        let g = toy_graph(17, 256, 6, BitCfg::new(8, 8, 8));
        assert!(search_folding(&g, &XC7A15T, 1e8).is_err(),
                "8-bit width-256 must not fit (paper §3.4)");
    }

    #[test]
    fn budget_respected_per_layer() {
        let g = toy_graph(11, 32, 3, BitCfg::new(3, 2, 8));
        let d = fold_for_target(&g, &XC7A15T, 1e8, 1e4)
            .unwrap()
            .unwrap();
        for l in &d.layers {
            assert!(l.cycles <= 1e4 as u64, "layer cycles {}", l.cycles);
        }
    }
}
