//! XPE-style analytic power model.
//!
//! P = P_static + Σ resource · toggle-activity · coefficient at the design
//! clock. Coefficients are calibrated so a mostly-full XC7A15T design lands
//! in the paper's 0.3–0.6 W band (Table 3); what the experiments compare is
//! the *relative* power of selected vs reference designs, which is driven
//! by the resource/activity mechanism, not the absolute calibration.

use super::model::Design;

/// Static power of the Artix-7 15T at nominal conditions (W).
const P_STATIC_W: f64 = 0.072;
/// Dynamic coefficients at 100 MHz, full activity (W per unit).
const W_PER_LUT: f64 = 2.6e-5;
const W_PER_FF: f64 = 6.0e-6;
const W_PER_BRAM: f64 = 4.5e-3;
const W_PER_DSP: f64 = 2.2e-3;
/// Clock-tree + I/O floor for any active design (W).
const P_CLOCK_W: f64 = 0.04;

#[derive(Clone, Copy, Debug)]
pub struct PowerBreakdown {
    pub static_w: f64,
    pub clock_w: f64,
    pub logic_w: f64,
    pub bram_w: f64,
    pub dsp_w: f64,
    pub total_w: f64,
}

/// Estimate power for a folded design.
///
/// Activity: a streaming layer toggles while it computes; averaged over an
/// inference the duty of layer i is `cycles_i / latency`, so busier
/// (less-folded) pipelines burn proportionally more.
pub fn estimate_power(design: &Design, clock_hz: f64) -> PowerBreakdown {
    let f_scale = clock_hz / 1e8;
    let total_cycles = design.latency_cycles().max(1) as f64;
    let (mut logic, mut bram, mut dsp, mut ff) = (0.0, 0.0, 0.0, 0.0);
    for l in &design.layers {
        let duty = (l.cycles.max(1) as f64 / total_cycles).clamp(0.05, 1.0);
        logic += l.luts as f64 * W_PER_LUT * duty;
        ff += l.ffs as f64 * W_PER_FF * duty;
        bram += l.bram36 * W_PER_BRAM * (0.3 + 0.7 * duty);
        dsp += l.dsps as f64 * W_PER_DSP * duty;
    }
    let logic_w = (logic + ff) * f_scale;
    let bram_w = bram * f_scale;
    let dsp_w = dsp * f_scale;
    let total_w = P_STATIC_W + P_CLOCK_W * f_scale + logic_w + bram_w + dsp_w;
    PowerBreakdown {
        static_w: P_STATIC_W,
        clock_w: P_CLOCK_W * f_scale,
        logic_w,
        bram_w,
        dsp_w,
        total_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitCfg;
    use crate::synth::folding::{fold_for_target, tests::toy_graph};
    use crate::synth::model::XC7A15T;

    #[test]
    fn power_in_paper_band() {
        let g = toy_graph(11, 64, 3, BitCfg::new(4, 3, 8));
        let d = fold_for_target(&g, &XC7A15T, 1e8, 1e4).unwrap().unwrap();
        let pw = estimate_power(&d, 1e8);
        assert!(pw.total_w > 0.1 && pw.total_w < 0.7,
                "total {} W outside the paper's band", pw.total_w);
        assert!(pw.total_w > pw.static_w);
    }

    #[test]
    fn more_parallel_designs_burn_more() {
        let g = toy_graph(17, 128, 6, BitCfg::new(3, 2, 8));
        let slow =
            fold_for_target(&g, &XC7A15T, 1e8, 1e3).unwrap().unwrap();
        let fast =
            fold_for_target(&g, &XC7A15T, 1e8, 1e5).unwrap().unwrap();
        let pw_slow = estimate_power(&slow, 1e8);
        let pw_fast = estimate_power(&fast, 1e8);
        assert!(pw_fast.total_w >= pw_slow.total_w * 0.9,
                "fast {} slow {}", pw_fast.total_w, pw_slow.total_w);
    }

    #[test]
    fn scales_with_clock() {
        let g = toy_graph(3, 16, 1, BitCfg::new(4, 2, 8));
        let d = fold_for_target(&g, &XC7A15T, 1e8, 1e4).unwrap().unwrap();
        let p100 = estimate_power(&d, 1e8);
        let p50 = estimate_power(&d, 5e7);
        assert!(p50.total_w < p100.total_w);
        assert!(p50.total_w > P_STATIC_W);
    }
}
