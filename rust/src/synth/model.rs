//! Device + MVAU cost model.
//!
//! Each policy layer maps to one MVAU(rows, cols, PE, SIMD): PE output
//! channels are computed in parallel, each consuming SIMD inputs per cycle,
//! so one inference takes `(rows/PE) * (cols/SIMD)` cycles in that layer.
//! Resources follow FINN-R's published scaling:
//!
//! * MAC array: LUTs ∝ PE · SIMD · (w_bits · a_bits) (LUT-based multipliers
//!   below the DSP threshold, DSP48 blocks above it),
//! * weight memory: on-chip, rows·cols·w_bits, LUTRAM below a threshold,
//!   BRAM36 above,
//! * threshold memory: rows · (2^out_bits − 1) · acc_bits — the
//!   exponential-in-activation-bits term that makes 8-bit models not fit,
//! * FIFOs + control: FFs proportional to PE·(acc_bits) plus stream widths.

use crate::qir::{EdgeTy, QGraph};

/// FPGA device resources (Table 2).
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub dsps: u64,
    /// max achievable clock for a design that "meets timing" here (Hz);
    /// models the -1 speed grade at the paper's fixed 100 MHz
    pub fmax_hz: f64,
}

/// Artix-7 XC7A15T-FGG484-1 (paper Table 2).
pub const XC7A15T: Device = Device {
    name: "XC7A15T-FGG484-1",
    luts: 10_400,
    ffs: 20_800,
    bram36: 25.0,
    dsps: 45,
    fmax_hz: 1.2e8,
};

/// Folding choice for one layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerFold {
    /// parallel output channels; must divide padded rows
    pub pe: usize,
    /// parallel inputs per cycle; must divide padded cols
    pub simd: usize,
}

/// Per-layer resource/cycle estimate.
#[derive(Clone, Debug)]
pub struct MvauCost {
    pub rows: usize,
    pub cols: usize,
    pub fold: LayerFold,
    pub w_bits: u32,
    pub in_bits: u32,
    pub out_bits: u32,
    pub acc_bits: u32,
    pub cycles: u64,
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub dsps: u64,
}

/// FINN pads stream widths to neat multiples; the paper pads action dims to
/// multiples of 32 — we apply the same rule to rows of the final layer.
pub const PAD_MULTIPLE: usize = 32;

pub fn pad_to(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// DSP48 inference rule: bit products at or above this use DSPs when
/// available (Vivado synthesizes small products into LUTs).
const DSP_BIT_PRODUCT: u32 = 24; // e.g. 8x8 with wide acc goes DSP-ward
/// LUTRAM -> BRAM threshold per memory (bits)
const LUTRAM_MAX_BITS: u64 = 16_384;

/// Cost one layer under a folding choice.
/// `rows`/`cols` are the *padded* dimensions.
#[allow(clippy::too_many_arguments)]
pub fn cost_layer(rows: usize, cols: usize, fold: LayerFold, w_bits: u32,
                  in_bits: u32, out_bits: u32, acc_bits: u32,
                  dsps_available: u64) -> MvauCost {
    assert_eq!(rows % fold.pe, 0, "PE must divide rows");
    assert_eq!(cols % fold.simd, 0, "SIMD must divide cols");
    let cycles = (rows / fold.pe) as u64 * (cols / fold.simd) as u64;
    let macs = (fold.pe * fold.simd) as u64;

    // --- MAC array -----------------------------------------------------------
    let bit_product = w_bits * in_bits;
    let (mac_luts, dsps) = if bit_product >= DSP_BIT_PRODUCT {
        // one DSP48 can host one (or two narrow) MACs; spill to LUTs when
        // the device runs out
        let want = macs.div_ceil(2).max(1);
        let got = want.min(dsps_available);
        let spill = (want - got) * 2;
        (spill * (3 * bit_product as u64 + 8), got)
    } else {
        // LUT MAC: ~bit_product LUTs for the partial product + adder tree
        (macs * (bit_product as u64 + acc_bits as u64 / 4), 0)
    };

    // --- memories -------------------------------------------------------------
    let weight_bits = (rows * cols) as u64 * w_bits as u64;
    let nthresh = (1u64 << out_bits) - 1;
    let thresh_bits = rows as u64 * nthresh * acc_bits as u64;
    let mut bram = 0.0f64;
    let mut mem_luts = 0u64;
    for bits in [weight_bits, thresh_bits] {
        if bits == 0 {
            continue;
        }
        if bits <= LUTRAM_MAX_BITS {
            mem_luts += bits / 32; // LUTRAM: 32 bits / LUT (RAM32)
        } else {
            bram += bits as f64 / 36_864.0; // BRAM36 = 36 Kib
        }
    }
    // threshold comparators: PE comparators of acc_bits, pipelined over the
    // levels (FINN streams thresholds; comparator cost is per PE)
    let cmp_luts = fold.pe as u64 * acc_bits as u64;

    // --- control / FIFOs --------------------------------------------------------
    let ctrl_luts = 60 + (fold.pe + fold.simd) as u64 * 4;
    let fifo_ffs = (fold.simd as u64 * in_bits as u64
        + fold.pe as u64 * out_bits as u64) * 2;
    let acc_ffs = fold.pe as u64 * acc_bits as u64 * 2;
    let pipe_ffs = macs * 4;

    MvauCost {
        rows, cols, fold, w_bits, in_bits, out_bits, acc_bits,
        cycles,
        luts: mac_luts + mem_luts + cmp_luts + ctrl_luts,
        ffs: fifo_ffs + acc_ffs + pipe_ffs + 120,
        bram36: bram,
        dsps,
    }
}

/// A complete folded design (one policy on one device).
#[derive(Clone, Debug)]
pub struct Design {
    pub device: Device,
    pub clock_hz: f64,
    pub layers: Vec<MvauCost>,
}

impl Design {
    pub fn luts(&self) -> u64 {
        self.layers.iter().map(|l| l.luts).sum()
    }

    pub fn ffs(&self) -> u64 {
        self.layers.iter().map(|l| l.ffs).sum()
    }

    pub fn bram36(&self) -> f64 {
        self.layers.iter().map(|l| l.bram36).sum()
    }

    pub fn dsps(&self) -> u64 {
        self.layers.iter().map(|l| l.dsps).sum()
    }

    /// Sum of per-layer compute cycles + per-layer pipeline fill overhead.
    pub fn latency_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles + 4).sum()
    }

    /// Initiation interval: the slowest layer bounds steady-state
    /// throughput of the streaming pipeline.
    pub fn initiation_interval(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).max().unwrap_or(1).max(1)
    }

    pub fn fits(&self, headroom: f64) -> bool {
        let d = &self.device;
        (self.luts() as f64) <= d.luts as f64 * headroom
            && (self.ffs() as f64) <= d.ffs as f64 * headroom
            && self.bram36() <= d.bram36 * headroom
            && self.dsps() <= d.dsps
    }

    /// Timing model: dense LUT usage degrades routing; a design "meets
    /// timing" at `clock_hz` when utilization-derated fmax still clears it.
    pub fn meets_timing(&self) -> bool {
        let util = self.luts() as f64 / self.device.luts as f64;
        let derate = 1.0 - 0.35 * util.clamp(0.0, 1.0);
        self.device.fmax_hz * derate >= self.clock_hz
    }
}

/// Padded per-layer MVAU geometry — everything the cost model needs to
/// know about one layer, extracted from the IR's typed edges (stream
/// widths come from the edge lattices, the accumulator width from the
/// requant op) instead of from raw `IntPolicy` fields.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerGeom {
    pub rows: usize,
    pub cols: usize,
    pub w_bits: u32,
    pub in_bits: u32,
    pub out_bits: u32,
    pub acc_bits: u32,
}

/// Build the padded MVAU geometry for a verified graph (before folding).
pub fn layer_geometry(g: &QGraph) -> anyhow::Result<Vec<LayerGeom>> {
    let views = g.layers()?;
    let n = views.len();
    Ok(views
        .iter()
        .enumerate()
        .map(|(i, v)| LayerGeom {
            rows: if i + 1 == n {
                pad_to(v.rows, PAD_MULTIPLE)
            } else {
                v.rows
            },
            cols: v.cols,
            w_bits: v.w_bits,
            in_bits: v.in_edge.bits(),
            out_bits: EdgeTy::lattice(1, v.out_range).bits(),
            acc_bits: v.acc_bits,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding() {
        assert_eq!(pad_to(1, 32), 32);
        assert_eq!(pad_to(32, 32), 32);
        assert_eq!(pad_to(33, 32), 64);
    }

    #[test]
    fn cycles_scale_with_folding() {
        let full = cost_layer(64, 64, LayerFold { pe: 64, simd: 64 },
                              3, 3, 3, 16, 45);
        let half = cost_layer(64, 64, LayerFold { pe: 32, simd: 64 },
                              3, 3, 3, 16, 45);
        let seq = cost_layer(64, 64, LayerFold { pe: 1, simd: 1 },
                             3, 3, 3, 16, 45);
        assert_eq!(full.cycles, 1);
        assert_eq!(half.cycles, 2);
        assert_eq!(seq.cycles, 64 * 64);
        assert!(full.luts > half.luts, "parallelism costs area");
    }

    #[test]
    fn threshold_memory_exponential_in_out_bits() {
        let c4 = cost_layer(256, 256, LayerFold { pe: 4, simd: 8 },
                            4, 4, 4, 18, 45);
        let c8 = cost_layer(256, 256, LayerFold { pe: 4, simd: 8 },
                            4, 4, 8, 18, 45);
        assert!(c8.bram36 > 4.0 * c4.bram36.max(0.1),
                "c4={} c8={}", c4.bram36, c8.bram36);
    }

    #[test]
    fn ii_is_slowest_layer() {
        let d = Design {
            device: XC7A15T,
            clock_hz: 1e8,
            layers: vec![
                cost_layer(64, 64, LayerFold { pe: 8, simd: 8 }, 3, 3, 3,
                           16, 45),
                cost_layer(64, 64, LayerFold { pe: 1, simd: 1 }, 3, 3, 3,
                           16, 45),
            ],
        };
        assert_eq!(d.initiation_interval(), 64 * 64);
        assert!(d.latency_cycles() > 64 * 64);
    }

    #[test]
    fn paper_8bit_wide_model_exceeds_device() {
        // the paper's finding: width-256 8-8-8 models do not fit XC7A15T
        // (threshold memory alone blows the 25 BRAM budget)
        let layers = vec![
            cost_layer(256, 384, LayerFold { pe: 2, simd: 4 }, 8, 8, 8,
                       24, 45),
            cost_layer(256, 256, LayerFold { pe: 2, simd: 4 }, 8, 8, 8,
                       24, 45),
            cost_layer(32, 256, LayerFold { pe: 1, simd: 2 }, 8, 8, 8,
                       24, 45),
        ];
        let d = Design { device: XC7A15T, clock_hz: 1e8, layers };
        assert!(!d.fits(1.0), "8-bit wide model should exceed XC7A15T: \
                 bram={}", d.bram36());
    }

    #[test]
    fn low_bit_small_model_fits() {
        let layers = vec![
            cost_layer(16, 32, LayerFold { pe: 4, simd: 8 }, 3, 4, 3, 14,
                       45),
            cost_layer(16, 16, LayerFold { pe: 4, simd: 4 }, 3, 3, 3, 12,
                       45),
            cost_layer(32, 16, LayerFold { pe: 4, simd: 4 }, 3, 3, 8, 12,
                       45),
        ];
        let d = Design { device: XC7A15T, clock_hz: 1e8, layers };
        assert!(d.fits(1.0), "luts={} bram={}", d.luts(), d.bram36());
        assert!(d.meets_timing());
    }
}
