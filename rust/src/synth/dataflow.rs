//! Cycle-level dataflow simulator: cross-checks the analytic latency model.
//!
//! Simulates the streaming pipeline at output-vector granularity per layer:
//! each MVAU starts once its input FIFO holds a full frame, computes for
//! its folded cycle count, then pushes one frame downstream. The analytic
//! model says end-to-end latency = Σ(cycles + fill); the simulator executes
//! that schedule event-by-event — a disagreement means one of them is wrong
//! (property-tested in `rust/tests/props.rs`).

use super::model::Design;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Stage {
    WaitingInput,
    Computing { done_at: u64 },
    Done,
}

/// Simulate one inference through the folded pipeline; returns the cycle at
/// which the final frame leaves the last layer.
pub fn simulate_latency_cycles(design: &Design) -> u64 {
    const FILL: u64 = 4; // per-layer pipeline fill (matches the model)
    let n = design.layers.len();
    let mut stage = vec![Stage::WaitingInput; n];
    let mut frame_ready = vec![false; n + 1]; // [0] = network input
    frame_ready[0] = true;

    let mut clock: u64 = 0;
    let mut guard = 0u64;
    while stage.last() != Some(&Stage::Done) {
        // event-driven: find the next state change instead of ticking
        let mut next_event = u64::MAX;
        let mut progressed = false;
        for i in 0..n {
            match stage[i] {
                Stage::WaitingInput if frame_ready[i] => {
                    frame_ready[i] = false;
                    stage[i] = Stage::Computing {
                        done_at: clock + design.layers[i].cycles + FILL,
                    };
                    progressed = true;
                }
                Stage::Computing { done_at } if done_at <= clock => {
                    stage[i] = Stage::Done;
                    frame_ready[i + 1] = true;
                    progressed = true;
                }
                Stage::Computing { done_at } => {
                    next_event = next_event.min(done_at);
                }
                _ => {}
            }
        }
        if !progressed {
            if next_event == u64::MAX {
                break; // deadlock would be a bug; caught by the assert below
            }
            clock = next_event;
        }
        guard += 1;
        assert!(guard < 1_000_000, "dataflow simulation did not converge");
    }
    assert_eq!(stage.last(), Some(&Stage::Done), "pipeline deadlocked");
    clock
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::model::{cost_layer, Design, LayerFold, XC7A15T};

    fn design(cycles: &[(usize, usize, usize, usize)]) -> Design {
        let layers = cycles
            .iter()
            .map(|&(rows, cols, pe, simd)| {
                cost_layer(rows, cols, LayerFold { pe, simd }, 3, 3, 3, 14,
                           45)
            })
            .collect();
        Design { device: XC7A15T, clock_hz: 1e8, layers }
    }

    #[test]
    fn sim_matches_analytic_sum() {
        let d = design(&[(16, 8, 2, 2), (16, 16, 4, 4), (32, 16, 1, 2)]);
        assert_eq!(simulate_latency_cycles(&d), d.latency_cycles());
    }

    #[test]
    fn single_layer() {
        let d = design(&[(64, 64, 8, 8)]);
        assert_eq!(simulate_latency_cycles(&d), 64 + 4);
    }

    #[test]
    fn fully_parallel_is_fill_dominated() {
        let d = design(&[(16, 16, 16, 16), (16, 16, 16, 16)]);
        assert_eq!(simulate_latency_cycles(&d), 2 * (1 + 4));
    }
}
