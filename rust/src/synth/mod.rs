//! FPGA synthesis estimator — the FINN/Vivado substitute (DESIGN.md §3).
//!
//! A QIR backend: the estimator consumes a verified
//! [`crate::qir::QGraph`] — MVAU geometry comes from the graph's typed
//! edges and op metadata ([`model::layer_geometry`]) instead of from raw
//! `IntPolicy` fields. Models a FINN-style streaming dataflow build on
//! the Artix-7 XC7A15T at 100 MHz: one matrix-vector-activation unit
//! (MVAU) per layer with PE×SIMD folding, threshold-based
//! requantization memory, FIFO links, and an XPE-style analytic power
//! model. The throughput-driven folding search reproduces the paper's
//! §3.4 procedure: sweep target throughputs in powers of 10, let the
//! folding optimizer hit each target, retain the highest target that
//! fits the device and meets timing.
//!
//! The cost model is calibrated to the *mechanisms* FINN-R publishes
//! (threshold memory exponential in activation bits, LUT MACs proportional
//! to the bit product, II set by the slowest layer), so Table 3's relative
//! structure — who wins, by roughly what factor — is preserved rather than
//! absolute LUT counts.

pub mod dataflow;
pub mod folding;
pub mod model;
pub mod power;

pub use dataflow::simulate_latency_cycles;
pub use folding::{fold_geometry, search_folding, search_geometry,
                  FoldingChoice, SearchOutcome};
pub use model::{Design, Device, LayerFold, LayerGeom, MvauCost, XC7A15T};
pub use power::{estimate_power, PowerBreakdown};

use crate::qir::{self, QGraph, QirBackend};
use crate::quant::export::IntPolicy;

/// Full synthesis report for one policy (a Table 3 row).
#[derive(Clone, Debug)]
pub struct SynthReport {
    pub design: Design,
    pub power: PowerBreakdown,
    /// end-to-end latency (s) at the design clock
    pub latency_s: f64,
    /// peak throughput (actions / s), II-bound
    pub throughput: f64,
    /// energy per action (J)
    pub energy_per_action: f64,
    /// cycle count cross-checked by the dataflow simulator
    pub sim_cycles: u64,
}

/// Synthesize a verified graph: folding search at the given clock, then
/// power and the cycle-level simulation cross-check.
pub fn synthesize_graph(g: &QGraph, device: &Device, clock_hz: f64)
                        -> anyhow::Result<SynthReport> {
    g.verify()?;
    let outcome = search_folding(g, device, clock_hz)?;
    let design = outcome.design;
    let power = estimate_power(&design, clock_hz);
    let latency_cycles = design.latency_cycles();
    let ii = design.initiation_interval();
    let sim_cycles = simulate_latency_cycles(&design);
    let latency_s = sim_cycles as f64 / clock_hz;
    let throughput = clock_hz / ii as f64;
    Ok(SynthReport {
        design,
        power,
        latency_s,
        throughput,
        energy_per_action: power.total_w * latency_s,
        sim_cycles: sim_cycles.max(latency_cycles),
    })
}

/// Synthesize a policy — takes its graph from the shared
/// `lower → optimize(level) → verify → compile` path and forwards to
/// [`synthesize_graph`], returning the pass ledger alongside the
/// report so callers can surface per-pass cost deltas.
pub fn synthesize_with(policy: &IntPolicy, device: &Device,
                       clock_hz: f64, level: qir::OptLevel)
                       -> anyhow::Result<(SynthReport, qir::PassReport)> {
    let (g, passes) = qir::prepare(policy, level)?;
    Ok((synthesize_graph(&g, device, clock_hz)?, passes))
}

/// Synthesize a policy exactly as exported (no graph rewrites) — the
/// historical numbers; [`synthesize_with`] exposes the optimizing path.
pub fn synthesize(policy: &IntPolicy, device: &Device, clock_hz: f64)
                  -> anyhow::Result<SynthReport> {
    Ok(synthesize_with(policy, device, clock_hz, qir::OptLevel::None)?.0)
}

/// [`QirBackend`] for the synthesis estimator: compiling a graph yields
/// its Table-3 row on the configured device/clock.
pub struct Synthesize {
    pub device: Device,
    pub clock_hz: f64,
}

impl QirBackend for Synthesize {
    type Output = SynthReport;

    fn name(&self) -> &'static str {
        "synth"
    }

    fn compile(&self, g: &QGraph) -> anyhow::Result<SynthReport> {
        synthesize_graph(g, &self.device, self.clock_hz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::BitCfg;
    use crate::util::testkit;

    #[test]
    fn policy_and_graph_paths_agree() {
        let p = testkit::toy_policy(1, 3, 16, 1, BitCfg::new(4, 2, 8));
        let a = synthesize(&p, &XC7A15T, 1e8).unwrap();
        let b = synthesize_graph(&qir::lower(&p), &XC7A15T, 1e8).unwrap();
        assert_eq!(a.design.luts(), b.design.luts());
        assert_eq!(a.design.ffs(), b.design.ffs());
        assert_eq!(a.sim_cycles, b.sim_cycles);
        assert_eq!(a.throughput, b.throughput);
    }

    #[test]
    fn synthesize_backend_compiles_graphs() {
        let g = qir::lower(&testkit::toy_policy(1, 3, 16, 1,
                                                BitCfg::new(4, 2, 8)));
        let be = Synthesize { device: XC7A15T, clock_hz: 1e8 };
        assert_eq!(be.name(), "synth");
        let rep = be.compile(&g).unwrap();
        assert!(rep.design.fits(1.0));
        assert!(rep.throughput > 0.0);
    }
}
