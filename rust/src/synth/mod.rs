//! FPGA synthesis estimator — the FINN/Vivado substitute (DESIGN.md §3).
//!
//! Models a FINN-style streaming dataflow build of an [`IntPolicy`] on the
//! Artix-7 XC7A15T at 100 MHz: one matrix-vector-activation unit (MVAU) per
//! layer with PE×SIMD folding, threshold-based requantization memory, FIFO
//! links, and an XPE-style analytic power model. The throughput-driven
//! folding search reproduces the paper's §3.4 procedure: sweep target
//! throughputs in powers of 10, let the folding optimizer hit each target,
//! retain the highest target that fits the device and meets timing.
//!
//! The cost model is calibrated to the *mechanisms* FINN-R publishes
//! (threshold memory exponential in activation bits, LUT MACs proportional
//! to the bit product, II set by the slowest layer), so Table 3's relative
//! structure — who wins, by roughly what factor — is preserved rather than
//! absolute LUT counts.

pub mod dataflow;
pub mod folding;
pub mod model;
pub mod power;

pub use dataflow::simulate_latency_cycles;
pub use folding::{search_folding, FoldingChoice, SearchOutcome};
pub use model::{Design, Device, LayerFold, MvauCost, XC7A15T};
pub use power::{estimate_power, PowerBreakdown};

use crate::quant::export::IntPolicy;

/// Full synthesis report for one policy (a Table 3 row).
#[derive(Clone, Debug)]
pub struct SynthReport {
    pub design: Design,
    pub power: PowerBreakdown,
    /// end-to-end latency (s) at the design clock
    pub latency_s: f64,
    /// peak throughput (actions / s), II-bound
    pub throughput: f64,
    /// energy per action (J)
    pub energy_per_action: f64,
    /// cycle count cross-checked by the dataflow simulator
    pub sim_cycles: u64,
}

/// Synthesize a policy: folding search at the given clock, then power and
/// the cycle-level simulation cross-check.
pub fn synthesize(policy: &IntPolicy, device: &Device, clock_hz: f64)
                  -> anyhow::Result<SynthReport> {
    let outcome = search_folding(policy, device, clock_hz)?;
    let design = outcome.design;
    let power = estimate_power(&design, clock_hz);
    let latency_cycles = design.latency_cycles();
    let ii = design.initiation_interval();
    let sim_cycles = simulate_latency_cycles(&design);
    let latency_s = sim_cycles as f64 / clock_hz;
    let throughput = clock_hz / ii as f64;
    Ok(SynthReport {
        design,
        power,
        latency_s,
        throughput,
        energy_per_action: power.total_w * latency_s,
        sim_cycles: sim_cycles.max(latency_cycles),
    })
}
