//! # qcontrol
//!
//! Reproduction of *"Learning Quantized Continuous Controllers for Integer
//! Hardware"* (Kresse & Lampert, 2025) as a three-layer rust + JAX + Pallas
//! stack:
//!
//! * **L1** — a Pallas QDQ-linear kernel (build-time python, `python/compile/kernels/`).
//! * **L2** — JAX SAC/DDPG models with quantization-aware training, AOT-lowered
//!   to HLO text (`python/compile/`), loaded here via PJRT.
//! * **L3** — this crate: environment physics, replay, training orchestration,
//!   staged model selection, integer-only inference, and the FPGA synthesis
//!   estimator that regenerates the paper's tables and figures.
//!
//! Python never runs on the request path; after `make artifacts` the binary is
//! self-contained.

pub mod util;
pub mod envs;
pub mod physics;
pub mod replay;
pub mod quant;
pub mod intinfer;
pub mod qir;
pub mod policy;
pub mod synth;
pub mod runtime;
pub mod rl;
pub mod experiment;
pub mod coordinator;
pub mod reactor;
pub mod search;
pub mod fleet;
